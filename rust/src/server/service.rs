//! Threaded TCP server with a single-engine continuous-batching loop.
//!
//! Topology: one listener thread accepting connections, one reader thread
//! per connection parsing JSON lines, one engine thread owning the
//! [`Engine`] and stepping it while work exists. Responses (including
//! streaming `delta` events) are written by the engine thread through a
//! per-connection mutex-serialized write half ([`SharedStream`]), so the
//! hot loop never blocks on a slow client for longer than one line write
//! and reader-side error lines can never interleave with in-flight
//! deltas.
//!
//! Admission is validated on the engine thread ([`Engine::admissible`]):
//! malformed lines are rejected by the reader with structured error
//! events, over-long prompts / unsupported per-request overrides are
//! rejected before a queue entry is committed. A `cancel` op frees the
//! request's slot mid-decode (the request finishes with
//! `"finish":"cancel"`) or, for a still-queued request, removes the
//! queue entry and answers the cancelled `done` directly.
//!
//! Admitted requests wait in a bounded server-side queue and are
//! submitted to the engine as batch slots free up (mid-flight refill —
//! the engine's own queue never grows beyond its batch). Overload is
//! answered with structured errors: `queue_full` at the queue bound,
//! `shed` when a queued request overstays the configured deadline.
//! Every v2 `done` carries the SLO block
//! ([`super::protocol::SloStats`]): this request's queue wait, the
//! queue depth at completion, and running latency / queue-wait
//! percentiles.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{Engine, FinishReason, GenRequest, GenResult, SamplingParams};
use crate::tokenizer::Tokenizer;
use crate::trace::TraceRecorder;
use crate::util::stats::Series;

use super::protocol::{
    parse_line, render_cancel, render_delta, render_done_with, render_error,
    render_error_event, render_generate, render_record_ack, render_response,
    SloStats, WireError, WireMsg, WireResponse,
};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    /// trace recorder to attach to the engine at start; the v2 `record`
    /// op toggles its gate at runtime (`None` = tracing unavailable)
    pub trace: Option<Arc<TraceRecorder>>,
    /// bound on the server-side admission queue — a generate arriving
    /// while `queue_limit` requests already wait is answered with a
    /// structured `queue_full` error instead of growing the queue
    pub queue_limit: usize,
    /// when set, queued requests that wait longer than this are load-shed
    /// with a structured `shed` error instead of decoding stale work
    pub shed_after: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7077".into(),
            trace: None,
            queue_limit: 512,
            shed_after: None,
        }
    }
}

/// One connection's write half. The reader thread (protocol errors) and
/// the engine thread (deltas, results) both write to the socket; the
/// mutex keeps whole lines atomic so the JSON framing cannot interleave.
type SharedStream = Arc<Mutex<TcpStream>>;

fn send_line(stream: &SharedStream, line: &str) {
    if let Ok(mut s) = stream.lock() {
        let _ = writeln!(s, "{line}");
    }
}

struct GenJob {
    engine_id: u64,
    wire_id: u64,
    stream: SharedStream,
    request: GenRequest,
    streaming: bool,
    v1: bool,
}

enum Job {
    Generate(Box<GenJob>),
    Cancel { engine_id: u64, wire_id: u64 },
}

/// The serving front-end. Owns the engine on a dedicated thread.
pub struct Server {
    addr: std::net::SocketAddr,
    listener: TcpListener,
    job_tx: Sender<Job>,
    engine_handle: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
    shutdown: Arc<AtomicBool>,
    /// the trace recorder attached to the engine, if any — the v2
    /// `record` op flips its gate from connection threads
    trace: Option<Arc<TraceRecorder>>,
}

impl Server {
    /// Bind and spawn the engine thread. `addr` may use port 0 for an
    /// ephemeral port (tests); the bound address is available via
    /// [`Server::addr`].
    pub fn start(mut engine: Engine, tokenizer: Tokenizer, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        if let Some(rec) = &cfg.trace {
            engine.set_trace(rec.clone());
        }
        let (job_tx, job_rx) = channel::<Job>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let engine_handle = {
            let shutdown = shutdown.clone();
            let queue_limit = cfg.queue_limit.max(1);
            let shed_after = cfg.shed_after;
            std::thread::Builder::new()
                .name("specd-engine".into())
                .spawn(move || {
                    engine_loop(engine, tokenizer, job_rx, shutdown, queue_limit, shed_after)
                })
                .context("spawning engine thread")?
        };
        crate::info!("server listening on {addr}");
        Ok(Server {
            addr,
            listener,
            job_tx,
            engine_handle: std::sync::Mutex::new(Some(engine_handle)),
            shutdown,
            trace: cfg.trace,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Accept connections until `shutdown` is set (blocks the caller).
    pub fn serve_forever(&self) -> Result<()> {
        let next_id = AtomicU64::new(1);
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let stream = stream.context("accept")?;
            let tx = self.job_tx.clone();
            let id_base = next_id.fetch_add(1 << 20, Ordering::Relaxed);
            let trace = self.trace.clone();
            std::thread::spawn(move || {
                if let Err(e) = connection_loop(stream, tx, id_base, trace) {
                    crate::debug!("connection ended: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Signal shutdown and join the engine thread (in-flight requests
    /// finish first; the accept loop exits on the next connection
    /// attempt). Joining makes post-shutdown reads of shared state —
    /// e.g. a [`crate::trace::TraceRecorder`] snapshot — race-free: once
    /// this returns, the engine has recorded its last event.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.engine_handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn connection_loop(
    stream: TcpStream,
    tx: Sender<Job>,
    id_base: u64,
    trace: Option<Arc<TraceRecorder>>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let writer: SharedStream = Arc::new(Mutex::new(stream));
    // wire id -> engine id, for routing cancels (ids are per-connection).
    // Bounded: entries older than the last CANCEL_WINDOW requests are
    // evicted — such requests have long finished and a cancel for them
    // would be a no-op anyway.
    const CANCEL_WINDOW: usize = 1024;
    let mut ids: HashMap<u64, u64> = HashMap::new();
    let mut order: VecDeque<u64> = VecDeque::new();
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(WireMsg::Generate(wire)) => {
                n += 1;
                let engine_id = id_base + n;
                if ids.insert(wire.id, engine_id).is_none() {
                    order.push_back(wire.id);
                }
                if order.len() > CANCEL_WINDOW {
                    if let Some(old) = order.pop_front() {
                        ids.remove(&old);
                    }
                }
                let mut params = wire.params;
                if wire.v1 && params.seed.is_none() {
                    // v1 determinism contract: unseeded one-shot requests
                    // seed from their wire id (pre-v2 behaviour, unchanged)
                    params.seed = Some(wire.id);
                }
                let request = GenRequest::from_text(engine_id, wire.prompt, params);
                tx.send(Job::Generate(Box::new(GenJob {
                    engine_id,
                    wire_id: wire.id,
                    stream: writer.clone(),
                    request,
                    streaming: wire.stream,
                    v1: wire.v1,
                })))
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            }
            Ok(WireMsg::Record { id, enable }) => match &trace {
                Some(rec) => {
                    // the gate is an atomic on the shared recorder — no
                    // engine-thread round trip needed; events between
                    // toggles are simply dropped (safe: the checker only
                    // replays traces recorded from engine start)
                    rec.set_enabled(enable);
                    send_line(&writer, &render_record_ack(id, rec.is_enabled()));
                }
                None => {
                    send_line(
                        &writer,
                        &render_error_event(&WireError::new(
                            Some(id),
                            "no_recorder",
                            "server was started without --trace; recording unavailable",
                        )),
                    );
                }
            },
            Ok(WireMsg::Cancel { id }) => match ids.get(&id) {
                Some(&engine_id) => {
                    tx.send(Job::Cancel {
                        engine_id,
                        wire_id: id,
                    })
                    .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
                }
                None => {
                    send_line(
                        &writer,
                        &render_error_event(&WireError::new(
                            Some(id),
                            "unknown_id",
                            "no request with that id on this connection",
                        )),
                    );
                }
            },
            Err(err) => {
                // answer in the dialect the offending line spoke
                let reply = if err.v1 {
                    render_error(err.id, &err.msg)
                } else {
                    render_error_event(&err)
                };
                send_line(&writer, &reply);
            }
        }
    }
    Ok(())
}

struct Inflight {
    wire_id: u64,
    stream: SharedStream,
    streaming: bool,
    v1: bool,
    /// seconds this request waited in the server admission queue
    queue_wait: f64,
}

/// One admitted-but-not-yet-submitted request waiting for a batch slot.
struct Queued {
    job: Box<GenJob>,
    enqueued: Instant,
}

/// The serve loop's running SLO series (seconds, per finished request).
struct SloSeries {
    latency: Series,
    queue: Series,
}

impl SloSeries {
    fn stats(&self, queue_wait: f64, queue_depth: usize) -> SloStats {
        SloStats {
            queue_wait,
            queue_depth,
            latency: self.latency.summary(),
            queue: self.queue.summary(),
        }
    }
}

fn send_overload(job: &GenJob, code: &'static str, msg: String) {
    let err = WireError::new(Some(job.wire_id), code, msg);
    let line = if job.v1 {
        render_error(Some(job.wire_id), &err.msg)
    } else {
        render_error_event(&err)
    };
    send_line(&job.stream, &line);
}

fn engine_loop(
    mut engine: Engine,
    tokenizer: Tokenizer,
    rx: Receiver<Job>,
    shutdown: Arc<AtomicBool>,
    queue_limit: usize,
    shed_after: Option<Duration>,
) {
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut slo = SloSeries {
        latency: Series::new(),
        queue: Series::new(),
    };
    loop {
        if shutdown.load(Ordering::Relaxed) && inflight.is_empty() && queue.is_empty() {
            break;
        }
        // pull socket work; block briefly only when fully idle
        let mut got = false;
        loop {
            let job = if engine.active() == 0 && inflight.is_empty() && queue.is_empty() && !got
            {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(j) => j,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            got = true;
            match job {
                Job::Generate(mut job) => {
                    if let Some(text) = job.request.prompt_text.take() {
                        job.request.prompt_ids = tokenizer.encode(&text);
                    }
                    job.request = job.request.clone().tokenize_stops(&tokenizer);
                    // admission: validate against params rules + model
                    // limits before committing a queue entry, forwarding
                    // the engine's structured code (e.g.
                    // `method_gamma_conflict`) to the client verbatim
                    if let Err(err) = engine.admissible(&job.request) {
                        send_overload(&job, err.code, err.msg);
                        continue;
                    }
                    // backpressure: the queue is bounded; past the bound
                    // the client is told immediately rather than waiting
                    if queue.len() >= queue_limit {
                        send_overload(
                            &job,
                            "queue_full",
                            format!(
                                "admission queue is full ({queue_limit} waiting); retry later"
                            ),
                        );
                        continue;
                    }
                    queue.push_back(Queued {
                        job,
                        enqueued: Instant::now(),
                    });
                }
                Job::Cancel { engine_id, wire_id } => {
                    if let Some(pos) =
                        queue.iter().position(|q| q.job.engine_id == engine_id)
                    {
                        // still queued: remove the entry and answer the
                        // cancelled done directly — the engine never saw
                        // this request
                        let q = queue.remove(pos).expect("position is in range");
                        let wait = q.enqueued.elapsed().as_secs_f64();
                        slo.queue.push(wait);
                        let resp = WireResponse {
                            id: q.job.wire_id,
                            text: String::new(),
                            result: GenResult {
                                id: engine_id,
                                token_ids: Vec::new(),
                                finish: FinishReason::Cancelled,
                                steps: 0,
                                drafted: 0,
                                accepted: 0,
                                latency: 0.0,
                            },
                        };
                        let line = if q.job.v1 {
                            render_response(&resp)
                        } else {
                            let pstats = engine.pipeline_stats();
                            render_done_with(
                                &resp,
                                Some(&slo.stats(wait, queue.len())),
                                pstats.as_ref(),
                            )
                        };
                        send_line(&q.job.stream, &line);
                        crate::debug!("cancelled queued request {wire_id}");
                    } else if engine.cancel(engine_id) {
                        // the Cancelled result flows out via the normal
                        // result drain below
                        crate::debug!("cancelled request {wire_id}");
                    } else {
                        // raced natural completion (or an admission
                        // rejection) — the request was already answered;
                        // a late error event here would desync clients
                        // reading the shared response stream
                        crate::debug!("cancel for finished request {wire_id}");
                    }
                }
            }
        }

        // load-shedding: queued requests past the wait deadline are
        // answered with `shed` instead of decoding stale work
        if let Some(deadline) = shed_after {
            while let Some(pos) = queue.iter().position(|q| q.enqueued.elapsed() > deadline)
            {
                let q = queue.remove(pos).expect("position is in range");
                let waited = q.enqueued.elapsed();
                send_overload(
                    &q.job,
                    "shed",
                    format!(
                        "load shed after {} ms in queue (deadline {} ms)",
                        waited.as_millis(),
                        deadline.as_millis()
                    ),
                );
            }
        }

        // mid-flight refill: submit queued requests into freed batch
        // slots so the engine's own queue never outgrows its batch
        while engine.free_slots() > 0 {
            let Some(q) = queue.pop_front() else { break };
            let wait = q.enqueued.elapsed().as_secs_f64();
            let job = *q.job;
            inflight.insert(
                job.engine_id,
                Inflight {
                    wire_id: job.wire_id,
                    stream: job.stream,
                    streaming: job.streaming,
                    v1: job.v1,
                    queue_wait: wait,
                },
            );
            engine.submit(job.request);
        }

        if engine.active() == 0 && engine.pending() == 0 {
            // drain results produced without stepping (queue cancels)
            flush_results(&mut engine, &tokenizer, &mut inflight, &mut slo, queue.len());
            continue;
        }
        if let Err(e) = engine.step() {
            crate::error!("engine step failed: {e:#}");
            // fail all in-flight requests
            for (_eid, f) in inflight.drain() {
                let line = if f.v1 {
                    render_error(Some(f.wire_id), "engine failure")
                } else {
                    render_error_event(&WireError::new(
                        Some(f.wire_id),
                        "engine",
                        "engine failure",
                    ))
                };
                send_line(&f.stream, &line);
            }
            continue;
        }
        // streaming deltas for this step
        for (engine_id, toks) in engine.take_deltas() {
            if let Some(f) = inflight.get(&engine_id) {
                if f.streaming {
                    let text = tokenizer.decode(&toks);
                    send_line(&f.stream, &render_delta(f.wire_id, &text, toks.len()));
                }
            }
        }
        flush_results(&mut engine, &tokenizer, &mut inflight, &mut slo, queue.len());
    }
}

fn flush_results(
    engine: &mut Engine,
    tokenizer: &Tokenizer,
    inflight: &mut HashMap<u64, Inflight>,
    slo: &mut SloSeries,
    queue_depth: usize,
) {
    // engine-wide scheduler counters, snapshotted once per drain (the
    // pipeline block every done event of this flush carries)
    let pstats = engine.pipeline_stats();
    for result in engine.take_results() {
        if let Some(f) = inflight.remove(&result.id) {
            slo.latency.push(result.latency);
            slo.queue.push(f.queue_wait);
            let resp = WireResponse {
                id: f.wire_id,
                text: tokenizer.decode_until_stop(&result.token_ids),
                result,
            };
            let line = if f.v1 {
                render_response(&resp)
            } else {
                // percentiles over every request finished so far,
                // including this one (so the first done already has n=1)
                render_done_with(
                    &resp,
                    Some(&slo.stats(f.queue_wait, queue_depth)),
                    pstats.as_ref(),
                )
            };
            send_line(&f.stream, &line);
        }
    }
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one raw protocol line.
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.stream, "{line}")?;
        Ok(())
    }

    /// Bound how long [`Client::read_event`] blocks (`None` = forever).
    /// Test harnesses set this so a missing event fails instead of
    /// hanging the run.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Read the next server line as JSON (blocks).
    pub fn read_event(&mut self) -> Result<crate::util::json::Value> {
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        crate::util::json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Send a v2 generate line (responses are read via
    /// [`Client::read_event`]).
    pub fn send_generate(
        &mut self,
        id: u64,
        prompt: &str,
        params: &SamplingParams,
        stream: bool,
    ) -> Result<()> {
        self.send_line(&render_generate(id, prompt, params, stream))
    }

    /// Send a v2 cancel line for an earlier generate.
    pub fn send_cancel(&mut self, id: u64) -> Result<()> {
        self.send_line(&render_cancel(id))
    }

    /// v2 non-streaming request: send and block for its `done` (or
    /// `error`) event.
    pub fn request_v2(
        &mut self,
        id: u64,
        prompt: &str,
        params: &SamplingParams,
    ) -> Result<crate::util::json::Value> {
        self.send_generate(id, prompt, params, false)?;
        self.read_event()
    }

    /// v1 one-shot request (compatibility shim round-trip).
    pub fn request(
        &mut self,
        id: u64,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<crate::util::json::Value> {
        let line = crate::util::json::obj(vec![
            ("id", (id as i64).into()),
            ("prompt", prompt.into()),
            ("max_new_tokens", max_new_tokens.into()),
            ("temperature", crate::util::json::Value::Num(temperature as f64)),
        ])
        .dump();
        self.send_line(&line)?;
        self.read_event()
    }
}
