//! Threaded TCP server with a single-engine continuous-batching loop.
//!
//! Topology: one listener thread accepting connections, one reader thread
//! per connection parsing JSON lines, one engine thread owning the
//! [`Engine`] and stepping it while work exists. Responses (including
//! streaming `delta` events) are written by the engine thread through a
//! per-connection mutex-serialized write half ([`SharedStream`]), so the
//! hot loop never blocks on a slow client for longer than one line write
//! and reader-side error lines can never interleave with in-flight
//! deltas.
//!
//! Admission is validated on the engine thread ([`Engine::admissible`]):
//! malformed lines are rejected by the reader with structured error
//! events, over-long prompts / unsupported per-request overrides are
//! rejected before a slot is committed. A `cancel` op frees the request's
//! slot mid-decode; the request finishes with `"finish":"cancel"`.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::engine::{Engine, GenRequest, SamplingParams};
use crate::tokenizer::Tokenizer;
use crate::trace::TraceRecorder;
use crate::util::stats::Series;

use super::protocol::{
    parse_line, render_cancel, render_delta, render_done_with, render_error,
    render_error_event, render_generate, render_record_ack, render_response,
    WireError, WireMsg, WireResponse,
};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    /// trace recorder to attach to the engine at start; the v2 `record`
    /// op toggles its gate at runtime (`None` = tracing unavailable)
    pub trace: Option<Arc<TraceRecorder>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7077".into(),
            trace: None,
        }
    }
}

/// One connection's write half. The reader thread (protocol errors) and
/// the engine thread (deltas, results) both write to the socket; the
/// mutex keeps whole lines atomic so the JSON framing cannot interleave.
type SharedStream = Arc<Mutex<TcpStream>>;

fn send_line(stream: &SharedStream, line: &str) {
    if let Ok(mut s) = stream.lock() {
        let _ = writeln!(s, "{line}");
    }
}

struct GenJob {
    engine_id: u64,
    wire_id: u64,
    stream: SharedStream,
    request: GenRequest,
    streaming: bool,
    v1: bool,
}

enum Job {
    Generate(Box<GenJob>),
    Cancel { engine_id: u64, wire_id: u64 },
}

/// The serving front-end. Owns the engine on a dedicated thread.
pub struct Server {
    addr: std::net::SocketAddr,
    listener: TcpListener,
    job_tx: Sender<Job>,
    engine_handle: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
    shutdown: Arc<AtomicBool>,
    /// the trace recorder attached to the engine, if any — the v2
    /// `record` op flips its gate from connection threads
    trace: Option<Arc<TraceRecorder>>,
}

impl Server {
    /// Bind and spawn the engine thread. `addr` may use port 0 for an
    /// ephemeral port (tests); the bound address is available via
    /// [`Server::addr`].
    pub fn start(mut engine: Engine, tokenizer: Tokenizer, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        if let Some(rec) = &cfg.trace {
            engine.set_trace(rec.clone());
        }
        let (job_tx, job_rx) = channel::<Job>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let engine_handle = {
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("specd-engine".into())
                .spawn(move || engine_loop(engine, tokenizer, job_rx, shutdown))
                .context("spawning engine thread")?
        };
        crate::info!("server listening on {addr}");
        Ok(Server {
            addr,
            listener,
            job_tx,
            engine_handle: std::sync::Mutex::new(Some(engine_handle)),
            shutdown,
            trace: cfg.trace,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Accept connections until `shutdown` is set (blocks the caller).
    pub fn serve_forever(&self) -> Result<()> {
        let next_id = AtomicU64::new(1);
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let stream = stream.context("accept")?;
            let tx = self.job_tx.clone();
            let id_base = next_id.fetch_add(1 << 20, Ordering::Relaxed);
            let trace = self.trace.clone();
            std::thread::spawn(move || {
                if let Err(e) = connection_loop(stream, tx, id_base, trace) {
                    crate::debug!("connection ended: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Signal shutdown (in-flight requests finish; accept loop exits on
    /// the next connection attempt).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        let _ = self.engine_handle.lock().unwrap().take();
    }
}

fn connection_loop(
    stream: TcpStream,
    tx: Sender<Job>,
    id_base: u64,
    trace: Option<Arc<TraceRecorder>>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let writer: SharedStream = Arc::new(Mutex::new(stream));
    // wire id -> engine id, for routing cancels (ids are per-connection).
    // Bounded: entries older than the last CANCEL_WINDOW requests are
    // evicted — such requests have long finished and a cancel for them
    // would be a no-op anyway.
    const CANCEL_WINDOW: usize = 1024;
    let mut ids: HashMap<u64, u64> = HashMap::new();
    let mut order: VecDeque<u64> = VecDeque::new();
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(WireMsg::Generate(wire)) => {
                n += 1;
                let engine_id = id_base + n;
                if ids.insert(wire.id, engine_id).is_none() {
                    order.push_back(wire.id);
                }
                if order.len() > CANCEL_WINDOW {
                    if let Some(old) = order.pop_front() {
                        ids.remove(&old);
                    }
                }
                let mut params = wire.params;
                if wire.v1 && params.seed.is_none() {
                    // v1 determinism contract: unseeded one-shot requests
                    // seed from their wire id (pre-v2 behaviour, unchanged)
                    params.seed = Some(wire.id);
                }
                let request = GenRequest::from_text(engine_id, wire.prompt, params);
                tx.send(Job::Generate(Box::new(GenJob {
                    engine_id,
                    wire_id: wire.id,
                    stream: writer.clone(),
                    request,
                    streaming: wire.stream,
                    v1: wire.v1,
                })))
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            }
            Ok(WireMsg::Record { id, enable }) => match &trace {
                Some(rec) => {
                    // the gate is an atomic on the shared recorder — no
                    // engine-thread round trip needed; events between
                    // toggles are simply dropped (safe: the checker only
                    // replays traces recorded from engine start)
                    rec.set_enabled(enable);
                    send_line(&writer, &render_record_ack(id, rec.is_enabled()));
                }
                None => {
                    send_line(
                        &writer,
                        &render_error_event(&WireError::new(
                            Some(id),
                            "no_recorder",
                            "server was started without --trace; recording unavailable",
                        )),
                    );
                }
            },
            Ok(WireMsg::Cancel { id }) => match ids.get(&id) {
                Some(&engine_id) => {
                    tx.send(Job::Cancel {
                        engine_id,
                        wire_id: id,
                    })
                    .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
                }
                None => {
                    send_line(
                        &writer,
                        &render_error_event(&WireError::new(
                            Some(id),
                            "unknown_id",
                            "no request with that id on this connection",
                        )),
                    );
                }
            },
            Err(err) => {
                // answer in the dialect the offending line spoke
                let reply = if err.v1 {
                    render_error(err.id, &err.msg)
                } else {
                    render_error_event(&err)
                };
                send_line(&writer, &reply);
            }
        }
    }
    Ok(())
}

struct Inflight {
    wire_id: u64,
    stream: SharedStream,
    streaming: bool,
    v1: bool,
}

fn engine_loop(
    mut engine: Engine,
    tokenizer: Tokenizer,
    rx: Receiver<Job>,
    shutdown: Arc<AtomicBool>,
) {
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    // per-request wall latencies since server start; summarized into the
    // `latency_percentiles_ms` block of every v2 `done` event
    let mut latency = Series::new();
    loop {
        if shutdown.load(Ordering::Relaxed) && inflight.is_empty() {
            break;
        }
        // admit everything queued; block briefly when idle
        let mut got = false;
        loop {
            let job = if engine.active() == 0 && inflight.is_empty() && !got {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(j) => j,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            got = true;
            match job {
                Job::Generate(job) => {
                    let GenJob {
                        engine_id,
                        wire_id,
                        stream,
                        mut request,
                        streaming,
                        v1,
                    } = *job;
                    if let Some(text) = request.prompt_text.take() {
                        request.prompt_ids = tokenizer.encode(&text);
                    }
                    request = request.tokenize_stops(&tokenizer);
                    // admission: validate against params rules + model
                    // limits instead of decoding garbage
                    if let Err(msg) = engine.admissible(&request) {
                        let err = WireError::new(Some(wire_id), "rejected", msg);
                        let line = if v1 {
                            render_error(Some(wire_id), &err.msg)
                        } else {
                            render_error_event(&err)
                        };
                        send_line(&stream, &line);
                        continue;
                    }
                    inflight.insert(
                        engine_id,
                        Inflight {
                            wire_id,
                            stream,
                            streaming,
                            v1,
                        },
                    );
                    engine.submit(request);
                }
                Job::Cancel { engine_id, wire_id } => {
                    if engine.cancel(engine_id) {
                        // the Cancelled result flows out via the normal
                        // result drain below
                        crate::debug!("cancelled request {wire_id}");
                    } else {
                        // raced natural completion (or an admission
                        // rejection) — the request was already answered;
                        // a late error event here would desync clients
                        // reading the shared response stream
                        crate::debug!("cancel for finished request {wire_id}");
                    }
                }
            }
        }

        if engine.active() == 0 && engine.pending() == 0 {
            // drain results produced without stepping (queue cancels)
            flush_results(&mut engine, &tokenizer, &mut inflight, &mut latency);
            continue;
        }
        if let Err(e) = engine.step() {
            crate::error!("engine step failed: {e:#}");
            // fail all in-flight requests
            for (_eid, f) in inflight.drain() {
                let line = if f.v1 {
                    render_error(Some(f.wire_id), "engine failure")
                } else {
                    render_error_event(&WireError::new(
                        Some(f.wire_id),
                        "engine",
                        "engine failure",
                    ))
                };
                send_line(&f.stream, &line);
            }
            continue;
        }
        // streaming deltas for this step
        for (engine_id, toks) in engine.take_deltas() {
            if let Some(f) = inflight.get(&engine_id) {
                if f.streaming {
                    let text = tokenizer.decode(&toks);
                    send_line(&f.stream, &render_delta(f.wire_id, &text, toks.len()));
                }
            }
        }
        flush_results(&mut engine, &tokenizer, &mut inflight, &mut latency);
    }
}

fn flush_results(
    engine: &mut Engine,
    tokenizer: &Tokenizer,
    inflight: &mut HashMap<u64, Inflight>,
    latency: &mut Series,
) {
    for result in engine.take_results() {
        if let Some(f) = inflight.remove(&result.id) {
            latency.push(result.latency);
            let resp = WireResponse {
                id: f.wire_id,
                text: tokenizer.decode_until_stop(&result.token_ids),
                result,
            };
            let line = if f.v1 {
                render_response(&resp)
            } else {
                // percentiles over every request finished so far,
                // including this one (so the first done already has n=1)
                render_done_with(&resp, Some(&latency.summary()))
            };
            send_line(&f.stream, &line);
        }
    }
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one raw protocol line.
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.stream, "{line}")?;
        Ok(())
    }

    /// Read the next server line as JSON (blocks).
    pub fn read_event(&mut self) -> Result<crate::util::json::Value> {
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        crate::util::json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Send a v2 generate line (responses are read via
    /// [`Client::read_event`]).
    pub fn send_generate(
        &mut self,
        id: u64,
        prompt: &str,
        params: &SamplingParams,
        stream: bool,
    ) -> Result<()> {
        self.send_line(&render_generate(id, prompt, params, stream))
    }

    /// Send a v2 cancel line for an earlier generate.
    pub fn send_cancel(&mut self, id: u64) -> Result<()> {
        self.send_line(&render_cancel(id))
    }

    /// v2 non-streaming request: send and block for its `done` (or
    /// `error`) event.
    pub fn request_v2(
        &mut self,
        id: u64,
        prompt: &str,
        params: &SamplingParams,
    ) -> Result<crate::util::json::Value> {
        self.send_generate(id, prompt, params, false)?;
        self.read_event()
    }

    /// v1 one-shot request (compatibility shim round-trip).
    pub fn request(
        &mut self,
        id: u64,
        prompt: &str,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<crate::util::json::Value> {
        let line = crate::util::json::obj(vec![
            ("id", (id as i64).into()),
            ("prompt", prompt.into()),
            ("max_new_tokens", max_new_tokens.into()),
            ("temperature", crate::util::json::Value::Num(temperature as f64)),
        ])
        .dump();
        self.send_line(&line)?;
        self.read_event()
    }
}
