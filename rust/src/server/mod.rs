//! TCP serving front-end.
//!
//! JSON-lines protocol over plain TCP (the vendored crate set has no
//! tokio; the engine thread + per-connection reader threads and mpsc
//! channels give the same continuous-batching behaviour):
//!
//! ```text
//! -> {"id": 1, "prompt": "the scheduler", "max_new_tokens": 64, "temperature": 0.8}
//! <- {"id": 1, "text": "...", "tokens": 64, "steps": 17, "accept_rate": 0.61,
//!     "latency_ms": 12.3, "finish": "length"}
//! ```

pub mod protocol;
pub mod service;

pub use protocol::{parse_request, render_response, WireRequest, WireResponse};
pub use service::{Server, ServerConfig};
