//! TCP serving front-end.
//!
//! JSON-lines protocol over plain TCP (the vendored crate set has no
//! tokio; the engine thread + per-connection reader threads and mpsc
//! channels give the same continuous-batching behaviour). Protocol v2 is
//! a versioned envelope with per-request [`crate::engine::SamplingParams`],
//! streaming `delta`/`done` events and a `cancel` op:
//!
//! ```text
//! -> {"v":2, "op":"generate", "id":1, "prompt":"the scheduler",
//!     "stream":true, "params":{"max_new_tokens":64, "top_p":0.9}}
//! <- {"v":2, "event":"delta", "id":1, "text":" accepts", "tokens":8}
//! <- {"v":2, "event":"done", "id":1, "text":"...", "tokens":64,
//!     "steps":17, "accept_rate":0.61, "latency_ms":12.3, "finish":"length"}
//! -> {"v":2, "op":"cancel", "id":1}
//! ```
//!
//! v1 one-shot lines (no `"v"` key) keep working unchanged — see
//! [`protocol`] for the full framing reference.

pub mod protocol;
pub mod service;

pub use protocol::{
    parse_line, parse_params, params_to_json, render_response, WireError, WireMsg,
    WireRequest, WireResponse,
};
pub use service::{Client, Server, ServerConfig};
