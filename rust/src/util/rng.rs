//! PCG32 pseudo-random generator (O'Neill 2014, XSH-RR variant).
//!
//! The engine owns every random draw in the stack: drafted-token sampling,
//! acceptance thresholds, resampling and bonus draws are all uniforms
//! generated here and fed into the AOT graphs as inputs, so a run is
//! reproducible bit-for-bit from a single seed. The stream semantics match
//! `python/compile/gen_corpus.py::Pcg32` (pinned in tests below), which is
//! how the corpus generator and the rust workloads stay aligned.

/// PCG32: 64-bit state, 32-bit output, selectable stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Default stream used across the project (matches python side).
    pub const DEFAULT_STREAM: u64 = 54;

    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, Self::DEFAULT_STREAM)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` (modulo; n is small everywhere we use this —
    /// same bias tradeoff as the python generator, keeping streams aligned).
    pub fn below(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of entropy — safe to compare
    /// against CDF boundaries computed in f32 graphs.
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits.
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.uniform_f64().max(1e-300);
        let u2 = self.uniform_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent per-request stream from a base seed.
    pub fn derive(seed: u64, request_id: u64) -> Self {
        Self::new(seed ^ request_id.wrapping_mul(0x9E3779B97F4A7C15), request_id | 1)
    }

    /// The generator's exact stream position `(state, inc)`. Together
    /// with [`Pcg32::from_state`] this is how the trace layer records
    /// drawn uniforms *as positions*: a recorded `(state, inc)` replays
    /// every subsequent draw bit-for-bit, with no floats in the trace.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact recorded stream position.
    pub fn from_state(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    /// Fill a buffer with uniform f32s (hot path helper — no allocation).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for slot in out.iter_mut() {
            *slot = self.uniform_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_reference_stream() {
        // pinned from python/compile/gen_corpus.py::Pcg32(seed, stream=54)
        let mut r = Pcg32::new(42, 54);
        let got: Vec<u32> = (0..6).map(|_| r.next_u32()).collect();
        assert_eq!(
            got,
            vec![
                2707161783, 2068313097, 3122475824, 2211639955, 3215226955, 3421331566
            ]
        );
        let mut r = Pcg32::new(7, 54);
        let got: Vec<u32> = (0..3).map(|_| r.next_u32()).collect();
        assert_eq!(got, vec![2757016003, 1815248828, 428590333]);
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let u = r.uniform_f32();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Pcg32::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "{mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn derive_gives_distinct_streams() {
        let mut a = Pcg32::derive(9, 1);
        let mut b = Pcg32::derive(9, 2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg32::seeded(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(123);
        let mut b = Pcg32::seeded(123);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }
}
