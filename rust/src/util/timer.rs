//! Scoped profiler mirroring the paper's measurement methodology.
//!
//! §4.1: *"We measure the execution time within the entire call stack of
//! the speculative sampling function, including any nested function call
//! (e.g. softmax). The profiling times are summed over all decoding steps
//! and examples in a dataset, before the relative improvement is
//! calculated."*
//!
//! [`Profiler`] accumulates wall-time per named scope; nested scopes are
//! tracked with a `parent/child` path so "the entire call stack of the
//! sampling function" is one subtree sum. Overhead is one `Instant::now()`
//! pair + a mutex-guarded map update per scope exit (measured < 100ns,
//! see bench_substrate).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Debug, Default, Clone, Copy)]
pub struct ScopeStat {
    pub calls: u64,
    pub total: Duration,
}

/// Thread-safe scope accumulator.
#[derive(Debug, Default)]
pub struct Profiler {
    scopes: Mutex<HashMap<String, ScopeStat>>,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enter a scope; time is recorded when the guard drops.
    pub fn scope<'a>(&'a self, name: &str) -> ScopeGuard<'a> {
        ScopeGuard {
            profiler: self,
            name: name.to_string(),
            start: Instant::now(),
        }
    }

    /// Record an externally-measured duration.
    pub fn record(&self, name: &str, elapsed: Duration) {
        let mut scopes = self.scopes.lock().unwrap();
        let stat = scopes.entry(name.to_string()).or_default();
        stat.calls += 1;
        stat.total += elapsed;
    }

    pub fn get(&self, name: &str) -> ScopeStat {
        self.scopes
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or_default()
    }

    /// Total time of every scope whose path starts with `prefix` —
    /// the paper's "entire call stack" sum for a function.
    pub fn subtree_total(&self, prefix: &str) -> Duration {
        let scopes = self.scopes.lock().unwrap();
        scopes
            .iter()
            .filter(|(k, _)| k.as_str() == prefix || k.starts_with(&format!("{prefix}/")))
            .map(|(_, s)| s.total)
            .sum()
    }

    /// Exclusive total of exactly the named scope.
    pub fn total(&self, name: &str) -> Duration {
        self.get(name).total
    }

    pub fn reset(&self) {
        self.scopes.lock().unwrap().clear();
    }

    /// Sorted (name, stat) pairs for reporting.
    pub fn report(&self) -> Vec<(String, ScopeStat)> {
        let mut rows: Vec<_> = self
            .scopes
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        rows.sort_by(|a, b| b.1.total.cmp(&a.1.total));
        rows
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "{:<42} {:>10} {:>14} {:>12}\n",
            "scope", "calls", "total(ms)", "avg(us)"
        );
        for (name, stat) in self.report() {
            let total_ms = stat.total.as_secs_f64() * 1e3;
            let avg_us = if stat.calls > 0 {
                stat.total.as_secs_f64() * 1e6 / stat.calls as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{name:<42} {:>10} {total_ms:>14.3} {avg_us:>12.2}\n",
                stat.calls
            ));
        }
        out
    }
}

/// RAII guard recording elapsed time on drop.
pub struct ScopeGuard<'a> {
    profiler: &'a Profiler,
    name: String,
    start: Instant,
}

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        self.profiler.record(&self.name, self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_calls_and_time() {
        let p = Profiler::new();
        for _ in 0..3 {
            let _g = p.scope("verify");
            std::thread::sleep(Duration::from_millis(1));
        }
        let s = p.get("verify");
        assert_eq!(s.calls, 3);
        assert!(s.total >= Duration::from_millis(3));
    }

    #[test]
    fn subtree_sums_nested_scopes() {
        let p = Profiler::new();
        p.record("verify", Duration::from_millis(5));
        p.record("verify/softmax", Duration::from_millis(3));
        p.record("verify/kernel", Duration::from_millis(2));
        p.record("verifyX", Duration::from_millis(100)); // not a child
        assert_eq!(p.subtree_total("verify"), Duration::from_millis(10));
        assert_eq!(p.total("verify"), Duration::from_millis(5));
    }

    #[test]
    fn reset_clears() {
        let p = Profiler::new();
        p.record("a", Duration::from_millis(1));
        p.reset();
        assert_eq!(p.get("a").calls, 0);
    }

    #[test]
    fn report_sorted_by_total() {
        let p = Profiler::new();
        p.record("small", Duration::from_micros(10));
        p.record("big", Duration::from_millis(10));
        let rows = p.report();
        assert_eq!(rows[0].0, "big");
        assert!(p.render().contains("big"));
    }

    #[test]
    fn thread_safety() {
        let p = std::sync::Arc::new(Profiler::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = p.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        p.record("x", Duration::from_nanos(100));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.get("x").calls, 400);
    }
}
