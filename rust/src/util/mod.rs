//! Substrate utilities built in-tree.
//!
//! The build environment is fully offline with a minimal vendored crate
//! set (`xla` + `anyhow`), so the usual ecosystem crates (serde_json,
//! clap, criterion, proptest, rand) are implemented here at the size this
//! project needs. Each submodule is self-contained and unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
