//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest, tokenizer table, and the server wire protocol).
//!
//! Design notes: object key order is preserved (`Vec<(String, Value)>`)
//! so round-trips are stable; numbers are f64 (the manifest only carries
//! shapes/counts well inside 2^53).

use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest loading wants context.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

/// Convenience constructor for object literals.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        out.push(
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble utf8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c);
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Value::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert!(arr[1].get("b").unwrap().is_null());
    }

    #[test]
    fn string_escapes_round_trip() {
        let cases = ["a\"b", "tab\there", "nl\nnl", "back\\slash", "unicode: ✓"];
        for c in cases {
            let v = Value::Str(c.to_string());
            assert_eq!(parse(&v.dump()).unwrap(), v, "{c:?}");
        }
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"\\q\"", "[1]x"] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn dump_round_trips_structures() {
        let v = obj(vec![
            ("name", "verify_exact_b1_g5_v128".into()),
            ("g", 5i64.into()),
            ("ratio", Value::Num(0.125)),
            ("inputs", Value::Arr(vec![Value::Arr(vec!["float32".into()])])),
            ("flag", true.into()),
            ("none", Value::Null),
        ]);
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.dump(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Value::Num(32768.0).dump(), "32768");
        assert_eq!(Value::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn fuzz_round_trip_random_value_trees() {
        use crate::util::proptest::{forall, Config};
        use crate::util::rng::Pcg32;

        fn gen_value(rng: &mut Pcg32, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 1),
                2 => {
                    // mix integers and dyadic fractions (exact in f64)
                    let base = rng.below(100_000) as f64 - 50_000.0;
                    Value::Num(base / (1 << rng.below(8)) as f64)
                }
                3 => {
                    let chars = [
                        'a', 'Z', '0', ' ', '"', '\\', '\n', '\t', '✓', '😀', '{',
                    ];
                    let n = rng.below(12) as usize;
                    Value::Str((0..n).map(|_| *rng.choice(&chars)).collect())
                }
                4 => {
                    let n = rng.below(4) as usize;
                    Value::Arr((0..n).map(|_| gen_value(rng, depth - 1)).collect())
                }
                _ => {
                    let n = rng.below(4) as usize;
                    Value::Obj(
                        (0..n)
                            .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                            .collect(),
                    )
                }
            }
        }

        forall("json round trip", Config { cases: 200, ..Config::default() }, |rng, size| {
            let v = gen_value(rng, (size % 4) + 1);
            let dumped = v.dump();
            match parse(&dumped) {
                Ok(back) if back == v => Ok(()),
                Ok(back) => Err(format!("{v:?} -> {dumped} -> {back:?}")),
                Err(e) => Err(format!("{v:?} -> {dumped} -> parse error {e}")),
            }
        });
    }

    #[test]
    fn fuzz_parser_never_panics_on_garbage() {
        use crate::util::proptest::{forall, Config};
        forall("no panic", Config { cases: 300, ..Config::default() }, |rng, size| {
            let bytes: Vec<u8> = (0..size).map(|_| rng.below(256) as u8).collect();
            if let Ok(s) = std::str::from_utf8(&bytes) {
                let _ = parse(s); // must return, never panic
            }
            // and mutated near-valid documents
            let mut doc = br#"{"id":1,"prompt":"x","a":[1,2.5,null]}"#.to_vec();
            let idx = rng.below(doc.len() as u32) as usize;
            doc[idx] = rng.below(256) as u8;
            if let Ok(s) = std::str::from_utf8(&doc) {
                let _ = parse(s);
            }
            Ok(())
        });
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "artifacts": [
                {"name": "verify_exact_b1_g5_v128", "kind": "verify",
                 "inputs": [["float32", [1, 6, 128]]], "g": 5}
            ]
        }"#;
        let v = parse(doc).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("g").unwrap().as_usize(), Some(5));
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap()[1]
            .as_arr()
            .unwrap();
        let dims: Vec<i64> = shape.iter().map(|d| d.as_i64().unwrap()).collect();
        assert_eq!(dims, vec![1, 6, 128]);
    }
}
