//! Tiny declarative CLI argument parser (the vendored crate set has no
//! clap). Supports `--key value`, `--key=value`, boolean flags, and a
//! leading positional subcommand; renders `--help` from the spec.

use std::collections::HashMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
    pub is_flag: bool,
}

/// Declarative command description.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            args: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            default: Some(default),
            help,
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            default: None,
            help,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            default: Some("false"),
            help,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        for a in &self.args {
            let d = match a.default {
                Some(d) if !a.is_flag => format!(" (default: {d})"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{:<18} {}{}", a.name, a.help, d);
        }
        s
    }

    /// Parse `argv` (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        let mut values: HashMap<String, String> = HashMap::new();
        for a in &self.args {
            if let Some(d) = a.default {
                values.insert(a.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            let stripped = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("unexpected argument {tok:?}\n{}", self.usage()))?;
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = self
                .args
                .iter()
                .find(|a| a.name == key)
                .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
            let val = if spec.is_flag {
                inline_val.unwrap_or_else(|| "true".to_string())
            } else if let Some(v) = inline_val {
                v
            } else {
                i += 1;
                argv.get(i)
                    .cloned()
                    .ok_or_else(|| format!("--{key} needs a value"))?
            };
            values.insert(key.to_string(), val);
            i += 1;
        }
        for a in &self.args {
            if !values.contains_key(a.name) {
                return Err(format!("missing required --{}\n{}", a.name, self.usage()));
            }
        }
        Ok(Parsed { values })
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: HashMap<String, String>,
}

impl Parsed {
    pub fn str(&self, key: &str) -> &str {
        self.values
            .get(key)
            .unwrap_or_else(|| panic!("arg {key} not in spec"))
    }

    pub fn usize(&self, key: &str) -> Result<usize, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected integer, got {:?}", self.str(key)))
    }

    pub fn u64(&self, key: &str) -> Result<u64, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected integer, got {:?}", self.str(key)))
    }

    pub fn f64(&self, key: &str) -> Result<f64, String> {
        self.str(key)
            .parse()
            .map_err(|_| format!("--{key}: expected number, got {:?}", self.str(key)))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.str(key) == "true"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "run the engine")
            .opt("port", "7077", "tcp port")
            .opt("method", "exact", "verifier")
            .flag("verbose", "chatty")
            .req("seed", "rng seed")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cmd().parse(&argv(&["--seed", "7"])).unwrap();
        assert_eq!(p.usize("port").unwrap(), 7077);
        assert_eq!(p.str("method"), "exact");
        assert!(!p.flag("verbose"));
        let p = cmd()
            .parse(&argv(&["--seed=9", "--port=80", "--verbose"]))
            .unwrap();
        assert_eq!(p.u64("seed").unwrap(), 9);
        assert_eq!(p.usize("port").unwrap(), 80);
        assert!(p.flag("verbose"));
    }

    #[test]
    fn missing_required_is_error() {
        assert!(cmd().parse(&argv(&[])).unwrap_err().contains("--seed"));
    }

    #[test]
    fn unknown_option_is_error() {
        let e = cmd().parse(&argv(&["--seed", "1", "--nope", "2"])).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn value_type_errors() {
        let p = cmd().parse(&argv(&["--seed", "x"])).unwrap();
        assert!(p.u64("seed").is_err());
    }

    #[test]
    fn help_lists_options() {
        let u = cmd().usage();
        assert!(u.contains("--port"));
        assert!(u.contains("default: 7077"));
    }
}
