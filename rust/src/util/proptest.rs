//! Miniature property-testing harness (proptest is not in the vendored
//! crate set). Runs a property over N seeded random cases; on failure it
//! reports the failing seed so the case can be replayed exactly, and
//! performs a bounded "shrink" by retrying the property on smaller sizes
//! drawn from the same seed.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath flags)
//! use specd::util::proptest::{forall, Config};
//! forall("sum is commutative", Config::default(), |rng, size| {
//!     let a = rng.below(size.max(1) as u32);
//!     let b = rng.below(size.max(1) as u32);
//!     if a + b == b + a { Ok(()) } else { Err("not commutative".into()) }
//! });
//! ```

use super::rng::Pcg32;

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
    /// maximum "size" hint handed to the property (grows over cases)
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            base_seed: 0x5eed,
            max_size: 64,
        }
    }
}

/// Run `prop` over `cfg.cases` random cases; panics with replay info on the
/// first failure. The property receives a seeded RNG and a size hint that
/// ramps from 1 to `max_size` (small cases first — cheap shrinking).
pub fn forall<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let seed = cfg.base_seed.wrapping_add(case as u64);
        let mut rng = Pcg32::seeded(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // bounded shrink: retry with progressively smaller sizes on the
            // same seed and report the smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Pcg32::seeded(seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property {name:?} failed: {} \
                 [replay: seed={seed}, size={}; first failure at size={size}]",
                smallest.1, smallest.0
            );
        }
    }
}

/// Replay a single case (used in regression tests after a failure).
pub fn replay<F>(seed: u64, size: usize, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    let mut rng = Pcg32::seeded(seed);
    prop(&mut rng, size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", Config { cases: 50, ..Config::default() }, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay: seed=")]
    fn failing_property_reports_seed() {
        forall("fails on big", Config::default(), |_, size| {
            if size > 10 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0;
        let mut min_seen = usize::MAX;
        forall("sizes", Config { cases: 64, max_size: 64, ..Config::default() }, |_, s| {
            max_seen = max_seen.max(s);
            min_seen = min_seen.min(s);
            Ok(())
        });
        assert_eq!(min_seen, 1);
        assert!(max_seen >= 60);
    }

    #[test]
    fn replay_is_deterministic() {
        let f = |rng: &mut Pcg32, _s: usize| {
            let x = rng.next_u32();
            if x % 2 == 0 {
                Ok(())
            } else {
                Err(format!("odd {x}"))
            }
        };
        let a = replay(42, 3, f);
        let b = replay(42, 3, f);
        assert_eq!(a, b);
    }
}
