//! Streaming statistics + summaries for latency/throughput reporting.

/// Online accumulator (Welford) with raw-sample retention for percentiles.
///
/// Retains samples (f64) because every use in this project is bounded
/// (per-step timings over at most a few hundred thousand steps).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() as f64 - 1.0);
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        }
    }

    pub fn summary(&self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            std: self.std(),
            min: self.min(),
            max: self.max(),
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
        }
    }
}

/// Point-in-time summary of a [`Series`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// `"4.17±0.81ms"` formatting used by the Table 6 reproduction.
    pub fn mean_std_ms(&self) -> String {
        format!("{:.2}±{:.2}", self.mean * 1e3, self.std * 1e3)
    }
}

/// Relative improvement of `new` over `base` in percent, as the paper
/// reports it: `(base - new) / base * 100` (positive = faster).
pub fn rel_improvement_pct(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    (base - new) / base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_of_known_sequence() {
        let mut s = Series::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample std of that classic sequence = sqrt(32/7)
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Series::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
        let sum = s.summary();
        assert!((sum.p95 - 95.05).abs() < 1e-9);
        assert!(sum.p50 <= sum.p95 && sum.p95 <= sum.p99);
    }

    #[test]
    fn empty_and_single() {
        let s = Series::new();
        assert!(s.percentile(50.0).is_nan());
        let mut s = Series::new();
        s.push(3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.percentile(50.0), 3.0);
    }

    #[test]
    fn rel_improvement_matches_paper_convention() {
        // baseline 4.17ms -> exact 3.67ms is a ~12% improvement (Table 6)
        let pct = rel_improvement_pct(4.17, 3.67);
        assert!((pct - 11.99).abs() < 0.01, "{pct}");
        // regressions are negative
        assert!(rel_improvement_pct(1.0, 2.0) < 0.0);
    }

    #[test]
    fn summary_consistency() {
        let mut s = Series::new();
        for i in 0..1000 {
            s.push((i % 10) as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 1000);
        assert!((sum.mean - 4.5).abs() < 1e-12);
        assert_eq!(sum.min, 0.0);
        assert_eq!(sum.max, 9.0);
    }
}
