//! Benchmark harness (criterion is not in the vendored crate set).
//!
//! Warmup + timed iterations with outlier-robust reporting; every
//! `rust/benches/*.rs` target uses this. Measurement model: each sample is
//! one invocation of the closure, wall-clocked with `Instant`; reported
//! statistics come from [`crate::util::stats::Series`].

use std::time::{Duration, Instant};

use super::json::{obj, Value};
use super::stats::{Series, Summary};

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// stop early once this much time has been spent measuring
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 5,
            min_iters: 20,
            max_iters: 2000,
            max_time: Duration::from_secs(3),
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.summary.mean
    }

    /// JSON record for the `--json` bench mode: per-target
    /// mean/p50/p95/p99/std in µs plus the iteration count.
    pub fn to_json(&self) -> Value {
        let s = &self.summary;
        obj(vec![
            ("name", self.name.as_str().into()),
            ("iters", s.n.into()),
            ("mean_us", Value::Num(s.mean * 1e6)),
            ("p50_us", Value::Num(s.p50 * 1e6)),
            ("p95_us", Value::Num(s.p95 * 1e6)),
            ("p99_us", Value::Num(s.p99 * 1e6)),
            ("std_us", Value::Num(s.std * 1e6)),
        ])
    }

    pub fn row(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>8} iters  mean {:>10.3}us  p50 {:>10.3}us  p99 {:>10.3}us  std {:>8.3}us",
            self.name,
            s.n,
            s.mean * 1e6,
            s.p50 * 1e6,
            s.p99 * 1e6,
            s.std * 1e6,
        )
    }
}

/// Benchmark a closure; returns per-iteration timing stats.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut series = Series::new();
    let started = Instant::now();
    for i in 0..cfg.max_iters {
        let t = Instant::now();
        f();
        series.push(t.elapsed().as_secs_f64());
        if i + 1 >= cfg.min_iters && started.elapsed() > cfg.max_time {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: series.summary(),
    }
}

/// Convenience: run + print a row.
pub fn bench_report<F: FnMut()>(name: &str, cfg: BenchConfig, f: F) -> BenchResult {
    let r = bench(name, cfg, f);
    println!("{}", r.row());
    r
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a bench report JSON document (the `--json <path>` mode of the
/// bench targets — e.g. `BENCH_PR3.json` seeding the perf trajectory).
pub fn write_json(path: &std::path::Path, report: &Value) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", report.dump()))
}

/// The shared `--json <path>` / `--smoke` flags of the bench targets
/// (`bench_e2e`, `bench_verify`); see `docs/PERF.md` for the snapshot
/// contract they feed.
#[derive(Debug, Default)]
pub struct BenchOpts {
    pub json: Option<std::path::PathBuf>,
    pub smoke: bool,
}

impl BenchOpts {
    /// Parse from `std::env::args` (ignoring cargo's `--bench`
    /// pass-through and unknown flags, with a notice).
    pub fn from_args() -> Self {
        let mut opts = BenchOpts::default();
        let mut args = std::env::args().skip(1);
        while let Some(arg) = args.next() {
            match arg.as_str() {
                "--json" => {
                    let path = args.next().expect("--json needs a path");
                    opts.json = Some(std::path::PathBuf::from(path));
                }
                "--smoke" => opts.smoke = true,
                // cargo bench passes --bench through to the target
                "--bench" => {}
                other => eprintln!("ignoring unknown arg {other:?}"),
            }
        }
        opts
    }

    /// The measurement config this invocation asked for: single-iteration
    /// smoke timings (CI executability gate) or the full sampling run.
    pub fn config(&self) -> BenchConfig {
        if self.smoke {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 1,
                max_iters: 1,
                max_time: Duration::from_millis(500),
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                min_iters: 15,
                max_iters: 300,
                max_time: Duration::from_secs(2),
            }
        }
    }
}

/// Short git revision of the working tree, for the snapshot stamp
/// (trajectory tooling correlates snapshots with commits). A dirty
/// tree measures code no commit contains, so it is marked with a
/// `-dirty` suffix rather than silently attributed to HEAD.
pub fn git_rev() -> String {
    let git = |args: &[&str]| {
        std::process::Command::new("git")
            .args(args)
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
    };
    let Some(rev) = git(&["rev-parse", "--short", "HEAD"]) else {
        return "unknown".to_string();
    };
    let dirty = git(&["status", "--porcelain"]).is_some_and(|s| !s.trim().is_empty());
    if dirty {
        format!("{}-dirty", rev.trim())
    } else {
        rev.trim().to_string()
    }
}

/// Assemble the schema-1 snapshot envelope shared by every bench
/// target's `--json` mode: `{"schema":1,"git_rev":…,"bench":…,
/// "smoke":…}` plus the target's own sections. Consumers must check
/// `schema == 1 && !smoke` before trusting a file.
pub fn snapshot_envelope(bench: &str, smoke: bool, sections: Vec<(&str, Value)>) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![
        // schema version first: bump it whenever a key changes meaning,
        // so trajectory tooling can refuse formats it does not
        // understand instead of misreading them
        ("schema", 1i64.into()),
        ("git_rev", git_rev().into()),
        ("bench", bench.into()),
        ("smoke", smoke.into()),
    ];
    fields.extend(sections);
    obj(fields)
}

/// Markdown-style table printer shared by bench targets and `specd table`.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench(
            "noop-ish",
            BenchConfig {
                warmup_iters: 2,
                min_iters: 10,
                max_iters: 50,
                max_time: Duration::from_millis(200),
            },
            || {
                black_box((0..100).sum::<u64>());
            },
        );
        assert!(r.summary.n >= 10);
        assert!(r.summary.mean >= 0.0);
        assert!(r.summary.p50 <= r.summary.p99 + 1e-12);
    }

    #[test]
    fn bench_respects_time_budget() {
        let t = Instant::now();
        let r = bench(
            "sleepy",
            BenchConfig {
                warmup_iters: 0,
                min_iters: 3,
                max_iters: 10_000,
                max_time: Duration::from_millis(50),
            },
            || std::thread::sleep(Duration::from_millis(5)),
        );
        assert!(t.elapsed() < Duration::from_secs(2));
        assert!(r.summary.n < 10_000);
    }

    #[test]
    fn bench_result_serializes_to_json() {
        let r = bench(
            "json-ish",
            BenchConfig {
                warmup_iters: 0,
                min_iters: 5,
                max_iters: 10,
                max_time: Duration::from_millis(100),
            },
            || {
                black_box((0..50).sum::<u64>());
            },
        );
        let v = r.to_json();
        assert_eq!(v.get("name").unwrap().as_str(), Some("json-ish"));
        assert!(v.get("iters").unwrap().as_usize().unwrap() >= 5);
        for key in ["mean_us", "p50_us", "p95_us", "p99_us", "std_us"] {
            assert!(v.get(key).unwrap().as_f64().unwrap() >= 0.0, "{key}");
        }
        // round-trips through the JSON layer
        let parsed = crate::util::json::parse(&v.dump()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("json-ish"));
    }

    #[test]
    fn snapshot_envelope_carries_the_schema_stamp() {
        let v = snapshot_envelope(
            "bench_x",
            true,
            vec![("payload", Value::Num(1.0))],
        );
        assert_eq!(v.get("schema").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("bench").unwrap().as_str(), Some("bench_x"));
        assert_eq!(v.get("smoke").and_then(Value::as_bool), Some(true));
        assert!(v.get("git_rev").unwrap().as_str().is_some());
        assert!(v.get("payload").is_some());
        // round-trips through the JSON layer
        let parsed = crate::util::json::parse(&v.dump()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn bench_opts_config_smoke_is_single_iteration() {
        let smoke = BenchOpts {
            json: None,
            smoke: true,
        };
        let cfg = smoke.config();
        assert_eq!((cfg.warmup_iters, cfg.min_iters, cfg.max_iters), (1, 1, 1));
        let full = BenchOpts::default().config();
        assert!(full.max_iters > full.min_iters);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["method", "Δ% prof"]);
        t.row(vec!["exact".into(), "11.7%".into()]);
        t.row(vec!["sigmoid".into(), "71.9%".into()]);
        let s = t.render();
        assert!(s.contains("| method"));
        assert!(s.lines().count() == 4);
        assert!(s.contains("| sigmoid"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
