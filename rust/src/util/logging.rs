//! Leveled stderr logger (`SPECD_LOG=debug|info|warn|error`, default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX);

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != u8::MAX {
        return t;
    }
    let level = match std::env::var("SPECD_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    THRESHOLD.store(level, Ordering::Relaxed);
    level
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, module: &str, msg: &str) {
    if (level as u8) < threshold() {
        return;
    }
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let _ = writeln!(
        std::io::stderr(),
        "[{:>10}.{:03} {tag} {module}] {msg}",
        now.as_secs(),
        now.subsec_millis()
    );
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_level_silences_lower() {
        set_level(Level::Error);
        // just exercises the path; output is stderr-only
        log(Level::Debug, "test", "should be invisible");
        log(Level::Error, "test", "visible");
        set_level(Level::Info);
    }
}
