//! Pipelined-vs-serial parity: the PR 5 acceptance criterion,
//! widened by PR 10 to the depth-k speculation window.
//!
//! The pipelined decode scheduler overlaps future model dispatches
//! with the current step's CPU verification by *speculating* on
//! commits — up to `pipeline_depth` blocks ahead, salvaging per-slot
//! rows on partial barrier hits — which is only admissible because
//! its observable outputs are **bit-identical** to the serial loop
//! for any seed, window depth, and salvage mode. These tests assert
//! exactly that, over the simulated model pair
//! ([`Runtime::simulated`], no artifacts needed): committed tokens,
//! finish reasons, per-request step/draft/accept counters, the
//! per-step streaming delta sequence, and the engine-level stats —
//! across verification methods × seeds × batch sizes × draft/target
//! agreement levels × k ∈ {1,2,3} × salvage on/off, with stop
//! sequences, ragged γ pins, per-request overrides, and mid-decode
//! cancellation in the mix.

use std::sync::Arc;
use std::time::Duration;

use specd::engine::{
    Backend, Engine, EngineConfig, GenRequest, Mode, PipelineMode, SamplingParams,
};
use specd::runtime::{Runtime, SimSpec};
use specd::sampling::Method;
use specd::util::proptest::{forall, Config};

fn sim_spec(vocab: usize, agreement: f32) -> SimSpec {
    sim_spec_g(vocab, agreement, 6)
}

fn sim_spec_g(vocab: usize, agreement: f32, gmax: usize) -> SimSpec {
    SimSpec {
        vocab,
        seq_len: 96,
        gmax,
        batches: vec![1, 2, 3, 4],
        seed: 0xBEEF,
        agreement,
        model_delay: Duration::ZERO,
    }
}

fn engine(spec: &SimSpec, batch: usize, method: Method, pipeline: PipelineMode) -> Engine {
    engine_gamma(spec, batch, method, pipeline, 4, false)
}

fn engine_gamma(
    spec: &SimSpec,
    batch: usize,
    method: Method,
    pipeline: PipelineMode,
    gamma_init: usize,
    gamma_pinned: bool,
) -> Engine {
    engine_full(spec, batch, method, pipeline, gamma_init, gamma_pinned, 2, true)
}

#[allow(clippy::too_many_arguments)]
fn engine_full(
    spec: &SimSpec,
    batch: usize,
    method: Method,
    pipeline: PipelineMode,
    gamma_init: usize,
    gamma_pinned: bool,
    pipeline_depth: usize,
    pipeline_salvage: bool,
) -> Engine {
    let rt = Arc::new(Runtime::simulated(spec.clone()));
    Engine::new(
        rt,
        EngineConfig {
            pair: "sim".into(),
            batch,
            method,
            backend: Backend::Native,
            mode: Mode::Speculative,
            gamma_init,
            gamma_pinned,
            self_draft: false,
            pipeline,
            pipeline_depth,
            pipeline_salvage,
            seed: 11,
        },
    )
    .expect("sim engine")
}

/// Engine with an explicit speculation-window depth / salvage policy.
fn engine_depth(
    spec: &SimSpec,
    batch: usize,
    method: Method,
    pipeline: PipelineMode,
    depth: usize,
    salvage: bool,
) -> Engine {
    engine_full(spec, batch, method, pipeline, 4, false, depth, salvage)
}

/// Everything observable about one decode run: per-request results,
/// the per-step delta stream, and the engine-level counters.
#[derive(Debug, PartialEq)]
struct Observed {
    results: Vec<(u64, Vec<i32>, String, usize, usize, usize)>,
    deltas: Vec<Vec<(u64, Vec<i32>)>>,
    steps: usize,
    drafted: usize,
    accepted: usize,
    emitted: usize,
    finished: usize,
    gamma_min: f64,
    gamma_max: f64,
    gamma_mean: f64,
}

/// Drive an engine step by step (collecting the streaming deltas per
/// step, like the server loop does) until done.
fn run_observed(mut e: Engine, reqs: Vec<GenRequest>) -> Observed {
    for r in reqs {
        e.submit(r);
    }
    let mut deltas = Vec::new();
    let mut guard = 0;
    while e.active() > 0 || e.pending() > 0 {
        e.step().expect("step");
        deltas.push(e.take_deltas());
        guard += 1;
        assert!(guard < 10_000, "decode did not terminate");
    }
    let mut results: Vec<_> = e
        .take_results()
        .into_iter()
        .map(|r| {
            (
                r.id,
                r.token_ids,
                format!("{:?}", r.finish),
                r.steps,
                r.drafted,
                r.accepted,
            )
        })
        .collect();
    results.sort_by_key(|r| r.0);
    let g = e.stats.gamma_series.summary();
    Observed {
        results,
        deltas,
        steps: e.stats.steps,
        drafted: e.stats.drafted,
        accepted: e.stats.accepted,
        emitted: e.stats.emitted,
        finished: e.stats.finished,
        gamma_min: g.min,
        gamma_max: g.max,
        gamma_mean: g.mean,
    }
}

fn assert_parity(spec: &SimSpec, batch: usize, method: Method, reqs: &[GenRequest]) {
    let serial = run_observed(
        engine(spec, batch, method, PipelineMode::Off),
        reqs.to_vec(),
    );
    let piped = run_observed(
        engine(spec, batch, method, PipelineMode::On),
        reqs.to_vec(),
    );
    assert_eq!(
        serial, piped,
        "pipelined output diverged (batch={batch}, method={})",
        method.name()
    );
}

fn base_reqs(n: u64, max_new: usize, seed0: u64) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            GenRequest::new(
                i,
                vec![1, 3 + i as i32, 9, 14],
                SamplingParams::default()
                    .with_max_new_tokens(max_new)
                    .with_temperature(0.8)
                    .with_seed(seed0 + i),
            )
        })
        .collect()
}

#[test]
fn pipelined_bit_identical_across_methods_seeds_batches() {
    // the acceptance criterion, as a property: for random (method,
    // batch, seed, agreement, request shape), pipelined == serial on
    // every observable
    let methods = [
        Method::Exact,
        Method::Baseline,
        Method::sigmoid(-1e3, 1e3),
        Method::sigmoid16(-1e3, 1e3),
        // fp16 overflow: NaN τ rejects everything → prefetch never hits
        Method::sigmoid16(-1e5, 1e5),
    ];
    forall(
        "pipeline parity",
        Config { cases: 24, ..Config::default() },
        |rng, size| {
            let method = methods[rng.below(methods.len() as u32) as usize];
            let batch = 1 + (size % 3);
            let agreement = [0.5f32, 0.9, 0.99][rng.below(3) as usize];
            let vocab = 48 + (size % 2) * 16;
            let spec = sim_spec(vocab, agreement);
            let n = (batch as u64) + rng.below(1 + batch as u32) as u64;
            let max_new = 8 + rng.below(16) as usize;
            let mut reqs = base_reqs(n.max(1), max_new, 100 + rng.below(1000) as u64);
            // sprinkle per-request policy: temperature, top-k/p, γ caps
            for (k, r) in reqs.iter_mut().enumerate() {
                match k % 4 {
                    0 => r.params.temperature = 0.5,
                    1 => r.params = r.params.clone().with_top_k(12),
                    2 => r.params = r.params.clone().with_top_p(0.9),
                    _ => r.params = r.params.clone().with_gamma(3),
                }
            }
            let serial = run_observed(
                engine(&spec, batch, method, PipelineMode::Off),
                reqs.clone(),
            );
            let piped = run_observed(
                engine(&spec, batch, method, PipelineMode::On),
                reqs,
            );
            if serial != piped {
                return Err(format!(
                    "diverged: method={} batch={batch} agreement={agreement}",
                    method.name()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn pipelined_engine_actually_pipelines() {
    // guard against the scheduler silently never launching: at high
    // agreement the all-accept prediction must land often
    let spec = sim_spec(64, 0.99);
    let mut e = engine(&spec, 2, Method::Exact, PipelineMode::On);
    let results = e.generate(base_reqs(4, 24, 500)).unwrap();
    assert_eq!(results.len(), 4);
    let stats = e.pipeline_stats().expect("pipeline enabled");
    assert!(stats.chains > 0, "no chain was ever launched");
    assert!(stats.full_hits > 0, "no prefetch ever fully hit at 0.99 agreement");
    assert!(stats.blocks > 0, "no prefetched block was ever consumed");
    // and the serial engine reports no pipeline stats
    let off = engine(&spec, 2, Method::Exact, PipelineMode::Off);
    assert!(off.pipeline_stats().is_none());
}

#[test]
fn parity_with_stop_sequences_and_eos() {
    // stop sequences finish mid-step and can retract across a step
    // boundary — the prefetch must refuse those steps and the barrier
    // must keep deltas identical. Token-level stops (no tokenizer).
    let spec = sim_spec(48, 0.9);
    for batch in [1usize, 2] {
        let mut reqs = base_reqs(3, 20, 900);
        for (k, r) in reqs.iter_mut().enumerate() {
            // single- and multi-token stops drawn from the small vocab
            r.stop_ids = match k {
                0 => vec![vec![17]],
                1 => vec![vec![9, 4]],
                _ => vec![vec![5], vec![30, 2, 7]],
            };
        }
        assert_parity(&spec, batch, Method::Exact, &reqs);
    }
}

#[test]
fn parity_with_per_request_method_overrides() {
    // heterogeneous batches: per-slot method dispatch under the
    // pipeline, including the NaN-τ sigmoid16 override that rejects
    // every draft in its row (prediction always misses on that slot)
    let spec = sim_spec(64, 0.95);
    let mut reqs = base_reqs(4, 16, 700);
    reqs[1].params = reqs[1].params.clone().with_method(Method::sigmoid(-1e3, 1e3));
    reqs[2].params = reqs[2].params.clone().with_method(Method::sigmoid16(-1e5, 1e5));
    for batch in [2usize, 3] {
        assert_parity(&spec, batch, Method::Exact, &reqs);
    }
}

#[test]
fn parity_with_pinned_gamma_and_greedy_temps() {
    let spec = sim_spec(48, 0.9);
    let mut reqs = base_reqs(3, 18, 300);
    reqs[0].params = reqs[0].params.clone().pin_gamma(2);
    reqs[1].params = reqs[1].params.clone().with_temperature(0.0); // clamped
    reqs[2].params = reqs[2].params.clone().with_draft_temperature(0.1);
    assert_parity(&spec, 2, Method::Exact, &reqs);
}

#[test]
fn parity_under_mid_decode_cancel() {
    // cancel one active slot and one queued request after a few steps:
    // the slot-set epoch must invalidate any in-flight prefetch and the
    // remaining decode must stay bit-identical to the serial engine
    // doing the same dance
    let spec = sim_spec(64, 0.97);
    let run = |pipeline: PipelineMode| {
        let mut e = engine(&spec, 2, Method::Exact, pipeline);
        for r in base_reqs(5, 24, 40) {
            e.submit(r);
        }
        let mut deltas = Vec::new();
        let mut guard = 0;
        let mut cancel_outcomes = (false, false);
        let mut cancelled = false;
        while e.active() > 0 || e.pending() > 0 {
            e.step().expect("step");
            deltas.push(e.take_deltas());
            if !cancelled && guard == 2 {
                // id 0 is normally still active and id 4 still queued;
                // either may have finished/admitted already (EOS luck) —
                // record the outcomes, parity compares them too
                cancel_outcomes = (e.cancel(0), e.cancel(4));
                assert!(!e.cancel(99), "unknown id");
                cancelled = true;
            }
            guard += 1;
            assert!(guard < 10_000, "decode did not terminate");
        }
        let mut results: Vec<_> = e
            .take_results()
            .into_iter()
            .map(|r| (r.id, r.token_ids, format!("{:?}", r.finish)))
            .collect();
        results.sort_by_key(|r| r.0);
        (results, deltas, cancel_outcomes)
    };
    assert_eq!(run(PipelineMode::Off), run(PipelineMode::On));
}

#[test]
fn parity_when_queue_exceeds_slots() {
    // slot turnover: finishes + refills bump the epoch and discard
    // prefetches; outputs must stay identical through the churn
    let spec = sim_spec(48, 0.9);
    for method in [Method::Exact, Method::sigmoid(-1e3, 1e3)] {
        assert_parity(&spec, 2, method, &base_reqs(6, 12, 77));
    }
}

#[test]
fn ragged_uniform_pins_match_engine_pinned_gamma() {
    // the ragged-batch refactor's degenerate case, as a property: an
    // engine whose slots are all request-pinned to the same γ must be
    // bit-identical to the pre-ragged shared-γ path (engine-level
    // gamma_pinned) — across methods × seeds × B ∈ {1,2,4} × γ, for
    // both the serial and pipelined schedulers
    let methods = [
        Method::Exact,
        Method::Baseline,
        Method::sigmoid(-1e3, 1e3),
        Method::sigmoid16(-1e3, 1e3),
    ];
    forall(
        "ragged uniform-γ parity",
        Config { cases: 16, ..Config::default() },
        |rng, size| {
            let method = methods[rng.below(methods.len() as u32) as usize];
            let batch = [1usize, 2, 4][size % 3];
            let g = 2 + rng.below(4) as usize;
            let spec = sim_spec(64, [0.5f32, 0.9, 0.99][rng.below(3) as usize]);
            let max_new = 8 + rng.below(12) as usize;
            let seed0 = 100 + rng.below(1000) as u64;
            let pipeline = if rng.below(2) == 0 {
                PipelineMode::On
            } else {
                PipelineMode::Off
            };
            let shared = run_observed(
                engine_gamma(&spec, batch, method, pipeline, g, true),
                base_reqs(batch as u64, max_new, seed0),
            );
            let mut reqs = base_reqs(batch as u64, max_new, seed0);
            for r in &mut reqs {
                r.params = r.params.clone().pin_gamma(g);
            }
            let ragged = run_observed(
                engine_gamma(&spec, batch, method, pipeline, g, false),
                reqs,
            );
            if shared != ragged {
                return Err(format!(
                    "uniform per-slot pins diverged from shared γ: \
                     method={} batch={batch} γ={g}",
                    method.name()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn mixed_gamma_batch_admits_and_decodes() {
    // the PR 7 acceptance scenario: one batch, per-slot γ ∈ {2,5,7},
    // mixed verification methods — admits, decodes to completion, and
    // stays bit-identical between the serial and pipelined schedulers
    let spec = sim_spec_g(64, 0.95, 8);
    let mut reqs = base_reqs(3, 16, 610);
    reqs[0].params = reqs[0].params.clone().pin_gamma(2);
    reqs[1].params = reqs[1].params.clone().pin_gamma(5).with_method(Method::Baseline);
    reqs[2].params = reqs[2]
        .params
        .clone()
        .pin_gamma(7)
        .with_method(Method::sigmoid(-1e3, 1e3));
    let serial = run_observed(
        engine(&spec, 3, Method::Exact, PipelineMode::Off),
        reqs.clone(),
    );
    for r in &serial.results {
        assert!(!r.1.is_empty(), "every request must emit tokens");
    }
    assert_eq!(serial.results.len(), 3);
    // the γ series must actually reach the large pin (headroom permitting)
    assert!(serial.gamma_max >= 5.0, "γ=7 pin never took effect");
    let piped = run_observed(
        engine(&spec, 3, Method::Exact, PipelineMode::On),
        reqs,
    );
    assert_eq!(serial, piped, "mixed-γ pipelined decode diverged");
}

#[test]
fn ragged_refill_is_deterministic() {
    // mid-flight refill with heterogeneous γ: more requests than slots,
    // each queue drain admits into a batch whose other slots carry
    // different γ values — repeat runs and serial/pipelined must agree
    let spec = sim_spec_g(48, 0.9, 8);
    let reqs = || {
        let mut rs = base_reqs(7, 12, 88);
        for (k, r) in rs.iter_mut().enumerate() {
            r.params = r.params.clone().pin_gamma([2usize, 5, 7][k % 3]);
        }
        rs
    };
    let run = |pipeline: PipelineMode| {
        run_observed(engine(&spec, 3, Method::Exact, pipeline), reqs())
    };
    let a = run(PipelineMode::On);
    let b = run(PipelineMode::On);
    assert_eq!(a, b, "ragged refill schedule must be deterministic");
    let serial = run(PipelineMode::Off);
    assert_eq!(serial, a, "ragged refill diverged from serial");
}

#[test]
fn ragged_mixed_gamma_simd_on_off_parity() {
    // the SIMD verify kernels are bit-identical to scalar by contract;
    // this pins the contract where the lanes are hardest — ragged
    // per-slot γ pins {2,5,7} with lane-tail γ·V row shapes (V not a
    // multiple of the lane width) and queue-churn refills, under both
    // schedulers. SIMD is forced per engine via the verifier's kernel
    // config, not `SPECD_SIMD`, so parallel tests cannot race the env.
    use specd::sampling::kernels::{simd::SimdMode, KernelConfig};
    forall(
        "ragged γ × SIMD on/off parity",
        Config { cases: 12, ..Config::default() },
        |rng, size| {
            let vocab = [61usize, 67, 97][size % 3]; // lane-tail shapes
            let agreement = [0.5f32, 0.9, 0.97][rng.below(3) as usize];
            let spec = sim_spec_g(vocab, agreement, 8);
            let batch = 1 + size % 3;
            let max_new = 8 + rng.below(10) as usize;
            let seed0 = 200 + rng.below(900) as u64;
            let pipeline = if rng.below(2) == 0 {
                PipelineMode::On
            } else {
                PipelineMode::Off
            };
            let n = batch as u64 + rng.below(3) as u64;
            let reqs = || {
                let mut rs = base_reqs(n, max_new, seed0);
                for (k, r) in rs.iter_mut().enumerate() {
                    r.params = r.params.clone().pin_gamma([2usize, 5, 7][k % 3]);
                    if k % 2 == 0 {
                        let m = Method::sigmoid16(-1e3, 1e3);
                        r.params = r.params.clone().with_method(m);
                    }
                }
                rs
            };
            let run = |simd: SimdMode| {
                let mut e = engine(&spec, batch, Method::Exact, pipeline);
                e.set_kernel_config(KernelConfig { simd, ..KernelConfig::default() });
                run_observed(e, reqs())
            };
            if run(SimdMode::On) != run(SimdMode::Off) {
                return Err(format!(
                    "SIMD on/off diverged: V={vocab} batch={batch} pipeline={pipeline:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn deterministic_across_repeat_runs() {
    // the pipelined engine is deterministic with itself (hit/miss
    // scheduling noise must never leak into outputs)
    let spec = sim_spec(64, 0.9);
    let run = || {
        run_observed(
            engine(&spec, 2, Method::Exact, PipelineMode::On),
            base_reqs(4, 20, 1234),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn depth_k_salvage_matrix_bit_identical_to_serial() {
    // the PR 10 acceptance matrix: window depth k ∈ {1,2,3} × partial
    // adoption on/off × ragged γ pins × mid-decode cancel + queue
    // churn × methods — every cell bit-identical to the serial loop
    let spec = sim_spec_g(64, 0.9, 8);
    for method in [Method::Exact, Method::sigmoid16(-1e3, 1e3)] {
        let reqs = || {
            let mut rs = base_reqs(6, 14, 910);
            for (k, r) in rs.iter_mut().enumerate() {
                r.params = r.params.clone().pin_gamma([2usize, 5, 7][k % 3]);
            }
            rs[1].stop_ids = vec![vec![9, 4]];
            rs
        };
        let run = |pipeline: PipelineMode, depth: usize, salvage: bool| {
            let mut e = engine_depth(&spec, 3, method, pipeline, depth, salvage);
            for r in reqs() {
                e.submit(r);
            }
            let mut deltas = Vec::new();
            let mut guard = 0;
            let mut cancels = (false, false);
            while e.active() > 0 || e.pending() > 0 {
                e.step().expect("step");
                deltas.push(e.take_deltas());
                if guard == 2 {
                    // one live slot, one queued request — outcomes are
                    // part of the parity comparison
                    cancels = (e.cancel(0), e.cancel(5));
                }
                guard += 1;
                assert!(guard < 10_000, "decode did not terminate");
            }
            let mut results: Vec<_> = e
                .take_results()
                .into_iter()
                .map(|r| (r.id, r.token_ids, format!("{:?}", r.finish)))
                .collect();
            results.sort_by_key(|r| r.0);
            (results, deltas, cancels)
        };
        let serial = run(PipelineMode::Off, 1, true);
        for depth in [1usize, 2, 3] {
            for salvage in [true, false] {
                assert_eq!(
                    serial,
                    run(PipelineMode::On, depth, salvage),
                    "k={depth} salvage={salvage} method={} diverged",
                    method.name()
                );
            }
        }
    }
}

#[test]
fn partial_adoption_salvages_slots() {
    // at moderate agreement a batch-3 barrier regularly splits — some
    // slots full-accept while others miss. Partial adoption must
    // actually salvage the surviving slots' rows (not silently fall
    // back to all-or-nothing), and with salvage disabled a miss must
    // never partially adopt.
    let spec = sim_spec(64, 0.9);
    let mut e = engine_depth(&spec, 3, Method::Exact, PipelineMode::On, 2, true);
    let results = e.generate(base_reqs(6, 24, 330)).unwrap();
    assert_eq!(results.len(), 6);
    let stats = e.pipeline_stats().expect("pipeline enabled");
    assert!(
        stats.partial_hits > 0,
        "no barrier ever partially hit: {stats:?}"
    );
    assert!(
        stats.slots_salvaged > 0,
        "no slot rows were ever salvaged: {stats:?}"
    );
    // salvage counts into the slot-level effective rate: with both
    // salvaged and redone slots observed, the rate is strictly interior
    let eff = stats.effective_hit_rate();
    assert!(eff > 0.0 && eff < 1.0, "degenerate effective rate: {stats:?}");

    let mut off = engine_depth(&spec, 3, Method::Exact, PipelineMode::On, 2, false);
    off.generate(base_reqs(6, 24, 330)).unwrap();
    let stats = off.pipeline_stats().expect("pipeline enabled");
    assert_eq!(
        stats.partial_hits, 0,
        "salvage disabled must never partially adopt: {stats:?}"
    );
    assert_eq!(stats.slots_salvaged, 0, "{stats:?}");
}

#[test]
fn deeper_windows_consume_multiple_blocks_per_chain() {
    // at high agreement a depth-3 chain should regularly deliver all
    // three blocks: the per-depth counters prove the ring actually
    // runs past depth 1
    let spec = sim_spec(64, 0.99);
    let mut e = engine_depth(&spec, 2, Method::Exact, PipelineMode::On, 3, true);
    e.generate(base_reqs(4, 28, 510)).unwrap();
    let stats = e.pipeline_stats().expect("pipeline enabled");
    assert_eq!(stats.per_depth.len(), 3);
    assert!(
        stats.per_depth[1].consumed > 0,
        "no depth-2 block was ever consumed: {stats:?}"
    );
    assert!(
        stats.per_depth[2].consumed > 0,
        "no depth-3 block was ever consumed: {stats:?}"
    );
    assert!(stats.blocks >= stats.chains, "{stats:?}");
}
