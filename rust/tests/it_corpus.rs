//! Integration: the committed trace regression corpus.
//!
//! Exercises the `specd trace corpus` gate end to end: seeding a fresh
//! corpus directory (snapshot-test bootstrap), the steady-state verify
//! pass, `--regen`, and — the point of the gate — mutation tests
//! proving that perturbing a committed historical run (a flipped
//! committed token, a flipped refill flag, a shifted RNG stream
//! position) is flagged at the exact step, slot and field. Runs
//! artifact-free over the simulated model pair, so it is always on.

use std::path::{Path, PathBuf};

use specd::trace::corpus::{self, entries, regen_entry, verify_entry, CorpusEntry};
use specd::trace::format::{self, StepEvent};
use specd::trace::{Trace, TraceEvent};

/// A scratch corpus directory unique to one test (tests run in
/// parallel within this binary).
fn scratch(tag: &str) -> PathBuf {
    let name = format!("specd_it_corpus_{}_{tag}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The first registry entry, seeded into `dir`, with its committed
/// trace loaded back for mutation.
fn seeded_entry(dir: &Path) -> (CorpusEntry, Trace) {
    let entry = entries().remove(0);
    regen_entry(&entry, dir).expect("seed entry");
    let trace = format::load(&dir.join(format!("{}.sptr", entry.name))).expect("load seed");
    (entry, trace)
}

fn save(trace: &Trace, entry: &CorpusEntry, dir: &Path) {
    format::save_binary(trace, &dir.join(format!("{}.sptr", entry.name))).expect("save mutant");
}

/// 1-based decode-step number of event index `idx` (matching the
/// checker's numbering).
fn step_number(trace: &Trace, idx: usize) -> usize {
    trace.events[..=idx]
        .iter()
        .filter(|ev| matches!(ev, TraceEvent::Step(_)))
        .count()
}

/// Index + step number of the first step whose first slot committed a
/// token (so a token flip is observable).
fn step_with_commit(trace: &Trace) -> (usize, usize) {
    for (idx, ev) in trace.events.iter().enumerate() {
        if let TraceEvent::Step(s) = ev {
            if s.slots.first().is_some_and(|sl| !sl.committed.is_empty()) {
                return (idx, step_number(trace, idx));
            }
        }
    }
    panic!("no step committed tokens");
}

fn step_mut(trace: &mut Trace, idx: usize) -> &mut StepEvent {
    match &mut trace.events[idx] {
        TraceEvent::Step(s) => s,
        _ => panic!("event {idx} is not a step"),
    }
}

#[test]
fn gate_seeds_a_fresh_dir_then_verifies_clean() {
    let dir = scratch("seed");
    let report = corpus::run(&dir, None, false, |_| {}).expect("seed run");
    assert!(report.ok(), "seed run failed: {:?}", report.failures);
    assert_eq!(report.seeded, report.entries, "every entry should seed");
    assert_eq!(report.entries, entries().len());
    assert!(report.steps > 0 && report.tokens > 0);

    // steady state: the seeded files now gate byte-exactly
    let report = corpus::run(&dir, None, false, |_| {}).expect("verify run");
    assert!(report.ok(), "verify run failed: {:?}", report.failures);
    assert_eq!(report.seeded, 0, "second run must verify, not re-seed");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn regen_overwrites_and_the_next_verify_is_clean() {
    let dir = scratch("regen");
    let name = entries()[1].name;
    let report = corpus::run(&dir, Some(name), true, |_| {}).expect("regen");
    assert!(report.ok());
    assert_eq!(report.entries, 1);
    let out = verify_entry(&entries()[1], &dir);
    assert!(out.failure.is_none(), "{:?}", out.failure);
    assert!(!out.bootstrapped, "regen should have left a file to verify");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_entry_name_lists_the_registry() {
    let dir = scratch("name");
    let err = corpus::run(&dir, Some("nope"), false, |_| {}).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("nope"), "{msg}");
    for entry in entries() {
        assert!(msg.contains(entry.name), "{msg} missing {}", entry.name);
    }
}

#[test]
fn flipped_committed_token_in_corpus_file_is_flagged_at_exact_step() {
    let dir = scratch("flip_commit");
    let (entry, mut trace) = seeded_entry(&dir);
    let (idx, step_no) = step_with_commit(&trace);
    let slot = {
        let s = step_mut(&mut trace, idx);
        let sl = s.slots.first_mut().unwrap();
        sl.committed[0] ^= 1;
        sl.slot
    };
    save(&trace, &entry, &dir);
    let out = verify_entry(&entry, &dir);
    let failure = out.failure.expect("mutation missed");
    assert!(failure.contains("oracle replay of committed trace"), "{failure}");
    assert!(failure.contains(&format!("step {step_no} ")), "{failure}");
    assert!(failure.contains(&format!("slot {slot} ")), "{failure}");
    assert!(failure.contains("committed diverged"), "{failure}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_refill_flag_in_corpus_file_is_flagged() {
    let dir = scratch("flip_refill");
    // ragged_gamma_refill has queue churn, so refill-stamped admits exist
    let entry = entries().remove(1);
    regen_entry(&entry, &dir).expect("seed entry");
    let path = dir.join(format!("{}.sptr", entry.name));
    let mut trace = format::load(&path).expect("load seed");
    let mut flipped = false;
    for ev in &mut trace.events {
        if let TraceEvent::Admit(a) = ev {
            a.refill = !a.refill;
            flipped = true;
            break;
        }
    }
    assert!(flipped, "trace has no admit events");
    save(&trace, &entry, &dir);
    let out = verify_entry(&entry, &dir);
    let failure = out.failure.expect("mutation missed");
    assert!(failure.contains("refill diverged"), "{failure}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn perturbed_rng_position_in_corpus_file_is_flagged() {
    let dir = scratch("rng");
    let (entry, mut trace) = seeded_entry(&dir);
    let (idx, step_no) = step_with_commit(&trace);
    {
        let s = step_mut(&mut trace, idx);
        let sl = s.slots.first_mut().unwrap();
        sl.rng_state = sl.rng_state.wrapping_add(1);
    }
    save(&trace, &entry, &dir);
    let out = verify_entry(&entry, &dir);
    let failure = out.failure.expect("mutation missed");
    assert!(failure.contains(&format!("step {step_no} ")), "{failure}");
    assert!(failure.contains("rng diverged"), "{failure}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_corpus_file_does_not_verify() {
    // dropping the trailing event may or may not matter to the oracle,
    // but the byte-exact re-record compare must still refuse it
    let dir = scratch("trunc");
    let (entry, mut trace) = seeded_entry(&dir);
    trace.events.pop().expect("non-empty trace");
    save(&trace, &entry, &dir);
    let out = verify_entry(&entry, &dir);
    let failure = out.failure.expect("truncation missed");
    let caught = failure.contains("differ")
        || failure.contains("diverged")
        || failure.contains("unreplayable");
    assert!(caught, "{failure}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn committed_repo_corpus_is_green() {
    // the real gate over the real directory: seeds `rust/tests/corpus`
    // on a fresh checkout (files are then committed), verifies the
    // committed recordings byte-exactly thereafter
    let dir = corpus::default_dir();
    let report = corpus::run(&dir, None, false, |_| {}).expect("corpus run");
    assert!(report.ok(), "committed corpus failed: {:?}", report.failures);
    assert_eq!(report.entries, entries().len());
}
