//! Integration: the speculative decode engine over real artifacts.

use std::sync::Arc;

use specd::engine::{Backend, Engine, EngineConfig, FinishReason, GenRequest, Mode};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::tokenizer::Tokenizer;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::open_default().expect("run `make artifacts` first"))
}

fn tok() -> Tokenizer {
    Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")).unwrap()
}

fn config(method: Method, backend: Backend) -> EngineConfig {
    EngineConfig {
        pair: "base".into(),
        batch: 1,
        method,
        backend,
        mode: Mode::Speculative,
        gamma_init: 5,
        gamma_pinned: false,
        self_draft: false,
        seed: 7,
    }
}

fn reqs(tok: &Tokenizer, n: usize, max_new: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            GenRequest::new(
                i as u64,
                tok.encode("The scheduler accepts the drafted tokens"),
                max_new,
            )
            .with_temperature(0.7)
            .with_seed(100 + i as u64)
        })
        .collect()
}

#[test]
fn generates_and_respects_limits() {
    let rt = runtime();
    let t = tok();
    let mut engine = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let results = engine.generate(reqs(&t, 3, 24)).unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(!r.token_ids.is_empty());
        assert!(r.token_ids.len() <= 24);
        assert!(r.steps > 0);
        assert!(r.drafted >= r.accepted);
        if r.finish == FinishReason::Length {
            assert_eq!(r.token_ids.len(), 24);
        }
        // all tokens within vocab
        assert!(r.token_ids.iter().all(|&x| (0..128).contains(&x)));
    }
    // engine-level accounting is consistent
    let s = &engine.stats;
    assert_eq!(s.finished, 3);
    assert_eq!(
        s.emitted,
        results.iter().map(|r| r.token_ids.len()).sum::<usize>()
    );
}

#[test]
fn deterministic_given_seeds() {
    let rt = runtime();
    let t = tok();
    let gen = |rt: &Arc<Runtime>| {
        let mut e = Engine::new(rt.clone(), config(Method::Exact, Backend::Hlo)).unwrap();
        e.generate(reqs(&t, 2, 16)).unwrap()
    };
    let a = gen(&rt);
    let b = gen(&rt);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.token_ids, y.token_ids);
        assert_eq!(x.steps, y.steps);
    }
}

#[test]
fn exact_reproduces_baseline_token_for_token() {
    // the paper's central exactness claim, end-to-end through the engine
    let rt = runtime();
    let t = tok();
    let run = |method| {
        let mut e = Engine::new(rt.clone(), config(method, Backend::Hlo)).unwrap();
        e.generate(reqs(&t, 2, 32)).unwrap()
    };
    let base = run(Method::Baseline);
    let exact = run(Method::Exact);
    for (x, y) in base.iter().zip(&exact) {
        assert_eq!(x.token_ids, y.token_ids);
        assert_eq!(x.accepted, y.accepted);
        assert_eq!(x.steps, y.steps);
    }
}

#[test]
fn native_backend_statistically_matches_hlo_backend() {
    // Bit-identity of a single verification step is asserted in
    // it_runtime.rs. Across whole trajectories the two backends may split
    // at f32 ULP boundaries (XLA's vectorised reductions associate sums
    // differently from the sequential oracle), after which the sequences
    // legitimately diverge — so here we check distributional equivalence.
    let rt = runtime();
    let t = tok();
    let run = |backend| {
        let mut e = Engine::new(rt.clone(), config(Method::Exact, backend)).unwrap();
        let r = e.generate(reqs(&t, 3, 24)).unwrap();
        (r, e.stats.acceptance_rate())
    };
    let (hlo, acc_hlo) = run(Backend::Hlo);
    let (native, acc_native) = run(Backend::Native);
    assert_eq!(hlo.len(), native.len());
    for (a, b) in hlo.iter().zip(&native) {
        assert_eq!(a.token_ids.len(), b.token_ids.len()); // same max_new
    }
    assert!(
        (acc_hlo - acc_native).abs() < 0.25,
        "acceptance {acc_hlo} vs {acc_native}"
    );
}

#[test]
fn sigmoid_decodes_with_reasonable_acceptance() {
    let rt = runtime();
    let t = tok();
    let mut e = Engine::new(rt, config(Method::sigmoid(-1e3, 1e3), Backend::Hlo)).unwrap();
    let results = e.generate(reqs(&t, 2, 24)).unwrap();
    for r in &results {
        assert!(!r.token_ids.is_empty());
        let acc = r.acceptance_rate();
        assert!((0.0..=1.0).contains(&acc));
    }
    // sigma ratios compress toward 1 -> sigmoid accepts at least something
    assert!(e.stats.acceptance_rate() > 0.05);
}

#[test]
fn pinned_gamma_stays_fixed() {
    let rt = runtime();
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.gamma_init = 2;
    cfg.gamma_pinned = true;
    let mut e = Engine::new(rt, cfg).unwrap();
    e.generate(reqs(&t, 1, 16)).unwrap();
    let s = e.stats.gamma_series.summary();
    assert_eq!(s.min, 2.0);
    assert_eq!(s.max, 2.0);
}

#[test]
fn adaptive_gamma_moves_with_acceptance() {
    let rt = runtime();
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    e.generate(reqs(&t, 3, 40)).unwrap();
    let s = e.stats.gamma_series.summary();
    // the controller must have actually adapted at least once
    assert!(s.max > s.min || e.stats.steps < 3, "γ never moved: {s:?}");
}

#[test]
fn autoregressive_mode_decodes_one_token_per_step() {
    let rt = runtime();
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.mode = Mode::Autoregressive;
    let mut e = Engine::new(rt, cfg).unwrap();
    let results = e.generate(reqs(&t, 1, 12)).unwrap();
    assert_eq!(results[0].token_ids.len(), 12);
    assert_eq!(results[0].steps, 12);
    assert_eq!(results[0].drafted, 0);
}

#[test]
fn speculative_emits_more_tokens_per_step_than_autoregressive() {
    // the whole point of speculative decoding
    let rt = runtime();
    let t = tok();
    let mut spec = Engine::new(rt.clone(), config(Method::Exact, Backend::Hlo)).unwrap();
    let r1 = spec.generate(reqs(&t, 2, 32)).unwrap();
    let tps: f64 = r1.iter().map(|r| r.tokens_per_step()).sum::<f64>() / r1.len() as f64;
    assert!(tps > 1.0, "speculative tokens/step = {tps}");
}

#[test]
fn empty_prompt_uses_bos() {
    let rt = runtime();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let r = e
        .generate(vec![GenRequest::new(0, vec![], 8).with_temperature(0.8)])
        .unwrap();
    assert_eq!(r.len(), 1);
    assert!(!r[0].token_ids.is_empty());
}

#[test]
fn rejects_unknown_batch_size() {
    let rt = runtime();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.batch = 999;
    assert!(Engine::new(rt, cfg).is_err());
}

#[test]
fn self_speculative_drafting_decodes() {
    // §A.7: draft with the first half of the target's layers — no separate
    // draft network. Available only in the full artifact set.
    let rt = runtime();
    if rt.manifest.by_name("draft_self_step_base_b1").is_err() {
        eprintln!("skipping: draft_self artifacts not built (quick set)");
        return;
    }
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.self_draft = true;
    let mut e = Engine::new(rt, cfg).unwrap();
    let results = e.generate(reqs(&t, 2, 16)).unwrap();
    for r in &results {
        assert!(!r.token_ids.is_empty());
        assert!(r.drafted > 0);
    }
    // a half-depth draft of the same model should still get tokens accepted
    assert!(e.stats.acceptance_rate() > 0.05, "{}", e.stats.acceptance_rate());
}

#[test]
fn sigmoid16_overflow_is_catastrophic_but_safe() {
    // the Table 2 ±1e5 fp16 row: NaN tau rejects everything; the engine
    // must stay alive and emit (resampled) tokens at 1/step.
    let rt = runtime();
    if rt
        .manifest
        .verify("sigmoid16", 1, 5, rt.manifest.vocab_size)
        .is_err()
    {
        eprintln!("skipping: sigmoid16 artifacts not built (quick set)");
        return;
    }
    let t = tok();
    let mut e = Engine::new(
        rt,
        config(Method::sigmoid16(-1e5, 1e5), Backend::Hlo),
    )
    .unwrap();
    let results = e.generate(reqs(&t, 1, 10)).unwrap();
    assert_eq!(results[0].token_ids.len(), 10);
    assert_eq!(results[0].accepted, 0, "NaN tau must reject every draft");
    // and at a moderate scale fp16 behaves like f32 sigmoid
    let rt2 = runtime();
    let mut e2 = Engine::new(
        rt2,
        config(Method::sigmoid16(-1e3, 1e3), Backend::Hlo),
    )
    .unwrap();
    let r2 = e2.generate(reqs(&t, 1, 10)).unwrap();
    assert!(r2[0].accepted > 0);
}

#[test]
fn queue_larger_than_slots_drains_fully() {
    let rt = runtime();
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let results = e.generate(reqs(&t, 5, 10)).unwrap();
    assert_eq!(results.len(), 5);
    let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
}
