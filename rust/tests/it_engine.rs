//! Integration: the speculative decode engine over real artifacts.
//!
//! These tests need built artifacts (`make artifacts`); they skip with a
//! notice when the runtime cannot be opened.

use std::sync::Arc;

use specd::engine::{
    Backend, Engine, EngineConfig, FinishReason, GenRequest, Mode, SamplingParams,
};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::tokenizer::Tokenizer;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::open_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}

fn tok() -> Tokenizer {
    Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")).unwrap()
}

fn config(method: Method, backend: Backend) -> EngineConfig {
    EngineConfig {
        pair: "base".into(),
        batch: 1,
        method,
        backend,
        mode: Mode::Speculative,
        gamma_init: 5,
        gamma_pinned: false,
        self_draft: false,
        pipeline: specd::engine::PipelineMode::Auto,
        pipeline_depth: 2,
        pipeline_salvage: true,
        seed: 7,
    }
}

fn reqs(tok: &Tokenizer, n: usize, max_new: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            GenRequest::new(
                i as u64,
                tok.encode("The scheduler accepts the drafted tokens"),
                SamplingParams::default()
                    .with_max_new_tokens(max_new)
                    .with_temperature(0.7)
                    .with_seed(100 + i as u64),
            )
        })
        .collect()
}

#[test]
fn generates_and_respects_limits() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut engine = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let results = engine.generate(reqs(&t, 3, 24)).unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(!r.token_ids.is_empty());
        assert!(r.token_ids.len() <= 24);
        assert!(r.steps > 0);
        assert!(r.drafted >= r.accepted);
        if r.finish == FinishReason::Length {
            assert_eq!(r.token_ids.len(), 24);
        }
        // all tokens within vocab
        assert!(r.token_ids.iter().all(|&x| (0..128).contains(&x)));
    }
    // engine-level accounting is consistent
    let s = &engine.stats;
    assert_eq!(s.finished, 3);
    assert_eq!(
        s.emitted,
        results.iter().map(|r| r.token_ids.len()).sum::<usize>()
    );
}

#[test]
fn deterministic_given_seeds() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let gen = |rt: &Arc<Runtime>| {
        let mut e = Engine::new(rt.clone(), config(Method::Exact, Backend::Hlo)).unwrap();
        e.generate(reqs(&t, 2, 16)).unwrap()
    };
    let a = gen(&rt);
    let b = gen(&rt);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.token_ids, y.token_ids);
        assert_eq!(x.steps, y.steps);
    }
}

#[test]
fn exact_reproduces_baseline_token_for_token() {
    // the paper's central exactness claim, end-to-end through the engine
    let Some(rt) = runtime() else { return };
    let t = tok();
    let run = |method| {
        let mut e = Engine::new(rt.clone(), config(method, Backend::Hlo)).unwrap();
        e.generate(reqs(&t, 2, 32)).unwrap()
    };
    let base = run(Method::Baseline);
    let exact = run(Method::Exact);
    for (x, y) in base.iter().zip(&exact) {
        assert_eq!(x.token_ids, y.token_ids);
        assert_eq!(x.accepted, y.accepted);
        assert_eq!(x.steps, y.steps);
    }
}

#[test]
fn native_backend_statistically_matches_hlo_backend() {
    // Bit-identity of a single verification step is asserted in
    // it_runtime.rs. Across whole trajectories the two backends may split
    // at f32 ULP boundaries (XLA's vectorised reductions associate sums
    // differently from the sequential oracle), after which the sequences
    // legitimately diverge — so here we check distributional equivalence.
    let Some(rt) = runtime() else { return };
    let t = tok();
    let run = |backend| {
        let mut e = Engine::new(rt.clone(), config(Method::Exact, backend)).unwrap();
        let r = e.generate(reqs(&t, 3, 24)).unwrap();
        (r, e.stats.acceptance_rate())
    };
    let (hlo, acc_hlo) = run(Backend::Hlo);
    let (native, acc_native) = run(Backend::Native);
    assert_eq!(hlo.len(), native.len());
    for (a, b) in hlo.iter().zip(&native) {
        assert_eq!(a.token_ids.len(), b.token_ids.len()); // same max_new
    }
    assert!(
        (acc_hlo - acc_native).abs() < 0.25,
        "acceptance {acc_hlo} vs {acc_native}"
    );
}

#[test]
fn sigmoid_decodes_with_reasonable_acceptance() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::sigmoid(-1e3, 1e3), Backend::Hlo)).unwrap();
    let results = e.generate(reqs(&t, 2, 24)).unwrap();
    for r in &results {
        assert!(!r.token_ids.is_empty());
        let acc = r.acceptance_rate();
        assert!((0.0..=1.0).contains(&acc));
    }
    // sigma ratios compress toward 1 -> sigmoid accepts at least something
    assert!(e.stats.acceptance_rate() > 0.05);
}

#[test]
fn pinned_gamma_stays_fixed() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.gamma_init = 2;
    cfg.gamma_pinned = true;
    let mut e = Engine::new(rt, cfg).unwrap();
    e.generate(reqs(&t, 1, 16)).unwrap();
    let s = e.stats.gamma_series.summary();
    assert_eq!(s.min, 2.0);
    assert_eq!(s.max, 2.0);
}

#[test]
fn per_request_pinned_gamma_caps_the_step() {
    // same as above, but per-request: an adaptive engine serving a
    // pin_gamma(2) request must never draft more than 2 tokens per step
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let req = GenRequest::new(
        0,
        t.encode("The scheduler accepts the drafted tokens"),
        SamplingParams::default()
            .with_max_new_tokens(24)
            .with_temperature(0.7)
            .with_seed(3)
            .pin_gamma(2),
    );
    let results = e.generate(vec![req]).unwrap();
    assert!(!results[0].token_ids.is_empty());
    let s = e.stats.gamma_series.summary();
    assert!(s.max <= 2.0, "per-request γ pin ignored: {s:?}");
}

#[test]
fn adaptive_gamma_moves_with_acceptance() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    e.generate(reqs(&t, 3, 40)).unwrap();
    let s = e.stats.gamma_series.summary();
    // the controller must have actually adapted at least once
    assert!(s.max > s.min || e.stats.steps < 3, "γ never moved: {s:?}");
}

#[test]
fn stop_sequences_finish_and_trim() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let stops = ["e", " ", "a", "t"];
    let req = GenRequest::new(
        0,
        t.encode("The scheduler accepts"),
        SamplingParams::default()
            .with_max_new_tokens(32)
            .with_temperature(0.7)
            .with_seed(11)
            .with_stop(stops.iter().map(|s| s.to_string()).collect()),
    )
    .tokenize_stops(&t);
    let results = e.generate(vec![req]).unwrap();
    let r = &results[0];
    match r.finish {
        FinishReason::StopSeq => {
            let text = t.decode(&r.token_ids);
            for s in stops {
                assert!(!text.contains(s), "{text:?} contains trimmed stop {s:?}");
            }
        }
        // the model may legitimately emit EOS or run to length without
        // ever sampling a stop char (vanishingly rare with these stops)
        FinishReason::Stop | FinishReason::Length => {}
        other => panic!("unexpected finish {other:?}"),
    }
}

#[test]
fn cancel_frees_slots_and_queue() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let mut rs = reqs(&t, 2, 64);
    let r1 = rs.pop().unwrap();
    let r0 = rs.pop().unwrap();
    e.submit(r0);
    e.submit(r1); // batch-1 engine: request 1 waits in the queue
    e.step().unwrap();
    let c0 = e.cancel(0);
    let c1 = e.cancel(1);
    assert!(c1, "queued request must be cancellable");
    assert!(!e.cancel(42), "unknown ids are not cancellable");
    let results = e.take_results();
    assert_eq!(results.len(), 2);
    assert!(results.iter().any(|r| r.finish == FinishReason::Cancelled));
    if c0 {
        // the active request keeps its partial output
        let r = results.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.steps > 0);
    }
    // both slots and the queue are reclaimed; the engine keeps serving
    assert_eq!(e.active(), 0);
    assert_eq!(e.pending(), 0);
    let again = e.generate(reqs(&t, 1, 8)).unwrap();
    assert_eq!(again.len(), 1);
    assert!(!again[0].token_ids.is_empty());
}

#[test]
fn top_k_one_is_greedy_under_any_seed() {
    // top_k = 1 masks everything but the argmax of the target
    // distribution, so emitted tokens are the deterministic argmax chain
    // regardless of the sampling seed
    let Some(rt) = runtime() else { return };
    let t = tok();
    let run = |seed: u64| {
        let mut e = Engine::new(rt.clone(), config(Method::Exact, Backend::Hlo)).unwrap();
        let req = GenRequest::new(
            0,
            t.encode("The scheduler accepts"),
            SamplingParams::default()
                .with_max_new_tokens(16)
                .with_temperature(1.0)
                .with_seed(seed)
                .with_top_k(1),
        );
        e.generate(vec![req]).unwrap()
    };
    let a = run(1);
    let b = run(999);
    assert_eq!(a[0].token_ids, b[0].token_ids);
}

#[test]
fn per_request_method_override_decodes() {
    // an engine configured for exact verification serving a
    // sigmoid-override request (and admission must accept it)
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let req = GenRequest::new(
        0,
        t.encode("The scheduler accepts"),
        SamplingParams::default()
            .with_max_new_tokens(12)
            .with_temperature(0.7)
            .with_seed(4)
            .with_method(Method::sigmoid(-1e3, 1e3)),
    );
    assert!(e.admissible(&req).is_ok());
    let results = e.generate(vec![req]).unwrap();
    assert!(!results[0].token_ids.is_empty());
}

#[test]
fn per_request_method_override_honored_on_batched_engine() {
    // the lifted batch-1 restriction: a batch > 1 engine admits a
    // method override and dispatches it per-slot. The override here is
    // the fp16-overflow sigmoid16 (NaN τ rejects every draft), which is
    // observable per-slot: the overridden request accepts nothing while
    // its batch-mates keep accepting drafts.
    let Some(rt) = runtime() else { return };
    let batches = rt.manifest.model_batches("base");
    let Some(&b) = batches.iter().filter(|&&x| x > 1).min() else {
        eprintln!("skipping: no batch > 1 model artifacts (quick set)");
        return;
    };
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Native);
    cfg.batch = b;
    let mut e = Engine::new(rt, cfg).unwrap();
    let mut rs = reqs(&t, b, 12);
    rs[0].params.method = Some(Method::sigmoid16(-1e5, 1e5));
    for r in &rs {
        assert!(e.admissible(r).is_ok(), "override rejected at admission");
    }
    let results = e.generate(rs).unwrap();
    assert_eq!(results.len(), b);
    let overridden = results.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(
        overridden.accepted, 0,
        "NaN τ must reject every draft of the overridden slot"
    );
    assert!(
        results.iter().any(|r| r.id != 0 && r.accepted > 0),
        "batch-mates must keep their exact-method acceptance"
    );
}

#[test]
fn admissible_rejects_model_limit_violations() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    // prompt longer than model context S
    let huge = GenRequest::new(
        0,
        vec![5; 1_000_000],
        SamplingParams::default().with_max_new_tokens(4),
    );
    assert!(e.admissible(&huge).is_err());
    // params rules are enforced at admission too
    let bad = GenRequest::new(
        1,
        t.encode("x"),
        SamplingParams::default().with_max_new_tokens(0),
    );
    assert!(e.admissible(&bad).is_err());
    // γ override beyond the model's gmax
    let gbad = GenRequest::new(
        2,
        t.encode("x"),
        SamplingParams::default().with_max_new_tokens(4).with_gamma(10_000),
    );
    assert!(e.admissible(&gbad).is_err());
    // autoregressive engines reject top-k/top-p (the filter cannot reach
    // the target_step artifact's internal sampling)
    let Some(rt2) = runtime() else { return };
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.mode = Mode::Autoregressive;
    let ar = Engine::new(rt2, cfg).unwrap();
    let filtered = GenRequest::new(
        3,
        t.encode("x"),
        SamplingParams::default().with_max_new_tokens(4).with_top_k(5),
    );
    assert!(ar.admissible(&filtered).is_err());
    let plain = GenRequest::new(
        4,
        t.encode("x"),
        SamplingParams::default().with_max_new_tokens(4),
    );
    assert!(ar.admissible(&plain).is_ok());
}

#[test]
fn take_deltas_streams_committed_tokens() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let mut rs = reqs(&t, 1, 16);
    e.submit(rs.pop().unwrap());
    let mut streamed: Vec<i32> = Vec::new();
    let mut guard = 0;
    while e.active() > 0 || e.pending() > 0 {
        e.step().unwrap();
        for (id, toks) in e.take_deltas() {
            assert_eq!(id, 0);
            streamed.extend(toks);
        }
        guard += 1;
        assert!(guard < 1000, "decode did not terminate");
    }
    let results = e.take_results();
    assert_eq!(streamed, results[0].token_ids, "deltas must reassemble the output");
}

#[test]
fn autoregressive_mode_decodes_one_token_per_step() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.mode = Mode::Autoregressive;
    let mut e = Engine::new(rt, cfg).unwrap();
    let results = e.generate(reqs(&t, 1, 12)).unwrap();
    assert_eq!(results[0].token_ids.len(), 12);
    assert_eq!(results[0].steps, 12);
    assert_eq!(results[0].drafted, 0);
}

#[test]
fn speculative_emits_more_tokens_per_step_than_autoregressive() {
    // the whole point of speculative decoding
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut spec = Engine::new(rt.clone(), config(Method::Exact, Backend::Hlo)).unwrap();
    let r1 = spec.generate(reqs(&t, 2, 32)).unwrap();
    let tps: f64 = r1.iter().map(|r| r.tokens_per_step()).sum::<f64>() / r1.len() as f64;
    assert!(tps > 1.0, "speculative tokens/step = {tps}");
}

#[test]
fn empty_prompt_uses_bos() {
    let Some(rt) = runtime() else { return };
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let r = e
        .generate(vec![GenRequest::new(
            0,
            vec![],
            SamplingParams::default().with_max_new_tokens(8),
        )])
        .unwrap();
    assert_eq!(r.len(), 1);
    assert!(!r[0].token_ids.is_empty());
}

#[test]
fn rejects_unknown_batch_size() {
    let Some(rt) = runtime() else { return };
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.batch = 999;
    assert!(Engine::new(rt, cfg).is_err());
}

#[test]
fn self_speculative_drafting_decodes() {
    // §A.7: draft with the first half of the target's layers — no separate
    // draft network. Available only in the full artifact set.
    let Some(rt) = runtime() else { return };
    if rt.manifest.by_name("draft_self_step_base_b1").is_err() {
        eprintln!("skipping: draft_self artifacts not built (quick set)");
        return;
    }
    let t = tok();
    let mut cfg = config(Method::Exact, Backend::Hlo);
    cfg.self_draft = true;
    let mut e = Engine::new(rt, cfg).unwrap();
    let results = e.generate(reqs(&t, 2, 16)).unwrap();
    for r in &results {
        assert!(!r.token_ids.is_empty());
        assert!(r.drafted > 0);
    }
    // a half-depth draft of the same model should still get tokens accepted
    assert!(e.stats.acceptance_rate() > 0.05, "{}", e.stats.acceptance_rate());
}

#[test]
fn sigmoid16_overflow_is_catastrophic_but_safe() {
    // the Table 2 ±1e5 fp16 row: NaN tau rejects everything; the engine
    // must stay alive and emit (resampled) tokens at 1/step.
    let Some(rt) = runtime() else { return };
    if rt
        .manifest
        .verify("sigmoid16", 1, 5, rt.manifest.vocab_size)
        .is_err()
    {
        eprintln!("skipping: sigmoid16 artifacts not built (quick set)");
        return;
    }
    let t = tok();
    let mut e = Engine::new(
        rt,
        config(Method::sigmoid16(-1e5, 1e5), Backend::Hlo),
    )
    .unwrap();
    let results = e.generate(reqs(&t, 1, 10)).unwrap();
    assert_eq!(results[0].token_ids.len(), 10);
    assert_eq!(results[0].accepted, 0, "NaN tau must reject every draft");
    // and at a moderate scale fp16 behaves like f32 sigmoid
    let Some(rt2) = runtime() else { return };
    let mut e2 = Engine::new(
        rt2,
        config(Method::sigmoid16(-1e3, 1e3), Backend::Hlo),
    )
    .unwrap();
    let r2 = e2.generate(reqs(&t, 1, 10)).unwrap();
    assert!(r2[0].accepted > 0);
}

#[test]
fn queue_larger_than_slots_drains_fully() {
    let Some(rt) = runtime() else { return };
    let t = tok();
    let mut e = Engine::new(rt, config(Method::Exact, Backend::Hlo)).unwrap();
    let results = e.generate(reqs(&t, 5, 10)).unwrap();
    assert_eq!(results.len(), 5);
    let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
}
