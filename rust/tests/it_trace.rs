//! Integration: the deterministic trace record/replay subsystem.
//!
//! Records real pipelined decodes over the simulated model pair, then
//! exercises the full trace stack end to end: zero-divergence replay
//! across batch sizes / mixed methods / mid-decode cancels, lossless
//! binary <-> JSON-lines round-trips, and mutation tests proving the
//! oracle checker flags corrupted traces at the exact step and field.
//! Runs artifact-free (`Runtime::simulated`), so it is always on.

use specd::trace::format::{self, SlotStep, StepEvent};
use specd::trace::fuzz::{record_case, FuzzCase};
use specd::trace::{check, Trace, TraceEvent};

/// A schedule with enough going on to be worth checking: queue churn
/// (more requests than slots), per-request method overrides, and a
/// mid-decode cancel.
fn busy_case(batch: usize) -> FuzzCase {
    FuzzCase {
        batch,
        n_reqs: batch + 2,
        mixed_methods: true,
        cancels: vec![(2, 0)],
        seed: 5 + batch as u64,
        ..FuzzCase::default()
    }
}

fn record(case: &FuzzCase) -> Trace {
    let (trace, _rec) = record_case(case).expect("record");
    trace
}

/// Index of the `i`-th (0-based) Step event that has at least one slot.
fn nth_step(trace: &Trace, i: usize) -> usize {
    trace
        .events
        .iter()
        .enumerate()
        .filter(|(_, ev)| matches!(ev, TraceEvent::Step(s) if !s.slots.is_empty()))
        .map(|(idx, _)| idx)
        .nth(i)
        .expect("trace has enough steps")
}

/// 1-based decode-step number of event index `idx` (counting all Step
/// events, matching the checker's step numbering).
fn step_number(trace: &Trace, idx: usize) -> usize {
    trace.events[..=idx]
        .iter()
        .filter(|ev| matches!(ev, TraceEvent::Step(_)))
        .count()
}

fn step_mut(trace: &mut Trace, idx: usize) -> &mut StepEvent {
    match &mut trace.events[idx] {
        TraceEvent::Step(s) => s,
        _ => panic!("event {idx} is not a step"),
    }
}

/// A step whose first slot committed at least one token (so a token
/// flip is observable in `committed`).
fn step_with_commit(trace: &Trace) -> (usize, usize) {
    for (idx, ev) in trace.events.iter().enumerate() {
        if let TraceEvent::Step(s) = ev {
            if s.slots.first().is_some_and(|sl| !sl.committed.is_empty()) {
                return (idx, step_number(trace, idx));
            }
        }
    }
    panic!("no step committed tokens");
}

fn first_slot(s: &mut StepEvent) -> &mut SlotStep {
    s.slots.first_mut().expect("step has slots")
}

#[test]
fn pipelined_runs_replay_with_zero_divergence_across_batches() {
    for batch in [1usize, 2, 4] {
        let case = busy_case(batch);
        let trace = record(&case);
        let report = check(&trace)
            .unwrap_or_else(|e| panic!("batch {batch}: trace unreplayable: {e}"));
        assert!(
            report.ok(),
            "batch {batch}: {}",
            report.divergence.unwrap()
        );
        assert_eq!(report.requests, case.n_reqs, "batch {batch}");
        assert!(report.steps > 0 && report.tokens > 0, "batch {batch}");
        assert!(
            report.pipeline_events > 0,
            "batch {batch}: pipelined run recorded no scheduler events"
        );
        assert!(report.verify_events > 0, "batch {batch}");
        assert!(
            report.pipeline_adopts > 0,
            "batch {batch}: depth-2 run never adopted a prefetched block"
        );
    }
}

#[test]
fn flipped_adopt_salvage_flag_is_flagged_both_ways() {
    // the checker replays the speculation chain alongside the oracle:
    // an Adopt that claims a salvage the chain replay refutes — or
    // drops a slot the replay proves was adoptable — is a divergence
    // pinned to the `salvaged` field
    use specd::trace::format::PipelineEv;
    let trace = record(&busy_case(3));
    let adopts: Vec<usize> = trace
        .events
        .iter()
        .enumerate()
        .filter_map(|(i, ev)| {
            matches!(ev, TraceEvent::Pipeline(PipelineEv::Adopt { .. })).then_some(i)
        })
        .collect();
    assert!(!adopts.is_empty(), "no Adopt events recorded");
    let flip = |want: bool| -> Option<Trace> {
        for &idx in &adopts {
            if let TraceEvent::Pipeline(PipelineEv::Adopt { salvaged, .. }) = &trace.events[idx] {
                if let Some(pos) = salvaged.iter().position(|&s| s == want) {
                    let mut bad = trace.clone();
                    if let TraceEvent::Pipeline(PipelineEv::Adopt { salvaged, .. }) =
                        &mut bad.events[idx]
                    {
                        salvaged[pos] = !want;
                    }
                    return Some(bad);
                }
            }
        }
        None
    };
    let mut directions = 0;
    // salvaged -> redone: the chain replay proves the slot was adoptable
    if let Some(bad) = flip(true) {
        let d = check(&bad)
            .expect("replayable")
            .divergence
            .expect("dropped salvage missed");
        assert_eq!(d.field, "salvaged", "{d}");
        directions += 1;
    }
    // redone -> salvaged: a claimed salvage the chain replay refutes
    if let Some(bad) = flip(false) {
        let d = check(&bad)
            .expect("replayable")
            .divergence
            .expect("fabricated salvage missed");
        assert_eq!(d.field, "salvaged", "{d}");
        directions += 1;
    }
    assert!(directions > 0, "trace had no flippable salvage flags");
}

#[test]
fn mid_decode_cancel_is_recorded_and_replays() {
    let case = busy_case(2);
    let trace = record(&case);
    // the step-2 cancel of request 0 lands while it holds a slot
    let slot_cancels = trace
        .events
        .iter()
        .filter(|ev| matches!(ev, TraceEvent::Cancel { slot: Some(_), .. }))
        .count();
    assert!(slot_cancels >= 1, "expected an in-slot cancel event");
    let report = check(&trace).expect("replayable");
    assert!(report.ok(), "{}", report.divergence.unwrap());
    assert_eq!(report.cancels, slot_cancels);
}

#[test]
fn binary_and_jsonl_round_trips_are_lossless() {
    let trace = record(&busy_case(2));
    assert!(!trace.events.is_empty());

    let bin = format::to_binary(&trace);
    let back = format::from_binary(&bin).expect("binary decode");
    assert_eq!(back, trace, "binary round-trip changed the trace");

    let jsonl = format::to_jsonl(&trace);
    let back = format::from_jsonl(&jsonl).expect("jsonl decode");
    assert_eq!(back, trace, "jsonl round-trip changed the trace");

    // cross-format: binary -> jsonl -> binary is still identical
    let again = format::to_binary(&format::from_jsonl(&format::to_jsonl(&back)).unwrap());
    assert_eq!(again, bin);
}

#[test]
fn truncated_binary_is_an_error_not_a_panic() {
    let bin = format::to_binary(&record(&busy_case(1)));
    for cut in [bin.len() - 1, bin.len() - 3, bin.len() / 2, 7, 1] {
        let err = format::from_binary(&bin[..cut]);
        assert!(err.is_err(), "cut at {cut} decoded");
    }
    assert!(format::from_binary(b"not a trace").is_err());
}

#[test]
fn flipped_committed_token_is_flagged_at_the_exact_step() {
    let mut trace = record(&busy_case(2));
    let (idx, step_no) = step_with_commit(&trace);
    let slot = {
        let s = step_mut(&mut trace, idx);
        let sl = first_slot(s);
        sl.committed[0] ^= 1; // flip the low bit of the first token
        sl.slot
    };
    let report = check(&trace).expect("still structurally replayable");
    let d = report.divergence.expect("corruption missed");
    assert_eq!(d.step, step_no, "flagged at the wrong step: {d}");
    assert_eq!(d.slot, slot, "flagged the wrong slot: {d}");
    assert_eq!(d.field, "committed", "flagged the wrong field: {d}");
}

#[test]
fn flipped_verifier_output_token_is_flagged() {
    let mut trace = record(&busy_case(2));
    let idx = nth_step(&trace, 1);
    let step_no = step_number(&trace, idx);
    {
        let s = step_mut(&mut trace, idx);
        let sl = first_slot(s);
        sl.out_row[0] ^= 1;
    }
    let report = check(&trace).expect("replayable");
    let d = report.divergence.expect("corruption missed");
    assert_eq!(d.step, step_no, "{d}");
    // the flipped emitted row is caught as an oracle output mismatch
    // (or, if the flipped token also entered `committed`, there first —
    // either way the step must match exactly)
    assert!(
        d.field == "out_tokens" || d.field == "committed",
        "unexpected field: {d}"
    );
}

#[test]
fn perturbed_rng_position_is_flagged() {
    let mut trace = record(&busy_case(2));
    let idx = nth_step(&trace, 0);
    let step_no = step_number(&trace, idx);
    {
        let s = step_mut(&mut trace, idx);
        let sl = first_slot(s);
        sl.rng_state = sl.rng_state.wrapping_add(1);
    }
    let report = check(&trace).expect("replayable");
    let d = report.divergence.expect("corruption missed");
    assert_eq!(d.step, step_no, "{d}");
    assert_eq!(d.field, "rng", "{d}");
}

#[test]
fn wrong_method_is_flagged_even_on_all_accept_steps() {
    let mut trace = record(&busy_case(2));
    let idx = nth_step(&trace, 0);
    let step_no = step_number(&trace, idx);
    {
        let s = step_mut(&mut trace, idx);
        let sl = first_slot(s);
        sl.method = match sl.method {
            specd::sampling::Method::Exact => specd::sampling::Method::Baseline,
            _ => specd::sampling::Method::Exact,
        };
    }
    let report = check(&trace).expect("replayable");
    let d = report.divergence.expect("corruption missed");
    assert_eq!(d.step, step_no, "{d}");
    assert_eq!(d.field, "method", "{d}");
}

#[test]
fn ragged_mixed_gamma_trace_replays_and_validates_refills() {
    // the PR 7 acceptance run: per-request γ pins {2,5,7} over a
    // 3-slot batch with queue churn and mixed methods — the recorded
    // steps must be genuinely ragged, replay with zero divergences,
    // and carry refill-stamped admissions the checker validates
    let case = FuzzCase {
        batch: 3,
        n_reqs: 6,
        gmax: 8,
        pin_gammas: vec![2, 5, 7],
        mixed_methods: true,
        seed: 9,
        ..FuzzCase::default()
    };
    let trace = record(&case);
    let ragged_step = trace.events.iter().any(|ev| {
        matches!(ev, TraceEvent::Step(s)
            if s.slots.iter().any(|sl| sl.gamma != s.slots[0].gamma))
    });
    assert!(ragged_step, "schedule never produced a ragged step");
    let report = check(&trace).expect("replayable");
    assert!(report.ok(), "{}", report.divergence.unwrap());
    assert!(report.refills > 0, "queue churn must record refill admits");

    // a flipped refill flag must be flagged against replayed occupancy
    let mut bad = trace.clone();
    for ev in &mut bad.events {
        if let TraceEvent::Admit(a) = ev {
            a.refill = !a.refill;
            break;
        }
    }
    let d = check(&bad)
        .expect("replayable")
        .divergence
        .expect("refill flip missed");
    assert_eq!(d.field, "refill", "{d}");
}

#[test]
fn perturbed_slot_gamma_is_structurally_rejected() {
    // SlotStep.gamma is authoritative for row addressing; a γ that
    // disagrees with the recorded draft/output row sizes makes the
    // trace unreplayable (error, not a silent mis-replay)
    let mut trace = record(&busy_case(2));
    let idx = nth_step(&trace, 1);
    {
        let s = step_mut(&mut trace, idx);
        first_slot(s).gamma += 1;
    }
    assert!(check(&trace).is_err(), "inflated slot γ decoded anyway");
}

#[test]
fn serial_and_pipelined_recordings_are_interchangeable() {
    // same schedule, pipelining on vs off: the step/admit/cancel event
    // streams must be identical (the trace is schedule-independent);
    // only the pipeline markers differ
    let strip = |t: &Trace| -> Vec<TraceEvent> {
        t.events
            .iter()
            .filter(|ev| !matches!(ev, TraceEvent::Pipeline(_) | TraceEvent::Verify { .. }))
            .cloned()
            .collect()
    };
    let on = record(&busy_case(2));
    let off = record(&FuzzCase {
        pipeline: specd::engine::PipelineMode::Off,
        ..busy_case(2)
    });
    assert_eq!(strip(&on), strip(&off));
    let report = check(&off).expect("replayable");
    assert!(report.ok(), "{}", report.divergence.unwrap());
}
