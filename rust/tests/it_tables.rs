//! Integration: fast table-generation paths (the simulator-only tables
//! and the harness plumbing; full measured tables run via `specd table`).
//!
//! These tests need built artifacts (`make artifacts`); they skip with a
//! notice when the runtime cannot be opened.

use specd::engine::SamplingParams;
use specd::simulator::DeviceProfile;
use specd::tables::{generate, EvalContext, TableId};

fn ctx(n: usize) -> Option<EvalContext> {
    match EvalContext::open_default(n) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn t3_bandwidth_table_renders() {
    let Some(ctx) = ctx(2) else { return };
    let dev = DeviceProfile::by_name("a100").unwrap();
    let out = generate(TableId::T3, &ctx, dev).unwrap();
    assert!(out.contains("Table 3"));
    assert!(out.contains("GB/s"));
    // all six paper combos present
    for name in ["Whisper", "Llama2 7B", "Llama2 13B", "Qwen 7B", "Gemma 7B"] {
        assert!(out.contains(name), "missing {name} in:\n{out}");
    }
}

#[test]
fn t3_sigmoid_bandwidth_highest_per_row() {
    // parse the rendered table and check the Table-3 ordering claim
    let Some(ctx) = ctx(2) else { return };
    let dev = DeviceProfile::by_name("a100").unwrap();
    let out = generate(TableId::T3, &ctx, dev).unwrap();
    let mut checked = 0;
    for line in out.lines().filter(|l| l.starts_with('|') && l.contains("GB/s")) {
        let vals: Vec<f64> = line
            .split('|')
            .filter(|c| c.contains("GB/s"))
            .filter_map(|c| c.replace("GB/s", "").trim().parse::<f64>().ok())
            .collect();
        if vals.len() == 3 {
            let (base, _exact, sigmoid) = (vals[0], vals[1], vals[2]);
            assert!(
                sigmoid > base,
                "sigmoid bandwidth must exceed baseline: {line}"
            );
            checked += 1;
        }
    }
    assert!(checked >= 5, "only {checked} rows parsed");
}

#[test]
fn eval_context_opens_and_harness_runs_one_method() {
    use specd::engine::Backend;
    use specd::sampling::Method;
    use specd::tables::run_method;
    use specd::workload::{make_tasks, TaskKind};

    let Some(ctx) = ctx(2) else { return };
    let tasks = make_tasks(&ctx.corpus, TaskKind::Asr, 2, 9);
    let run = run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 2, true).unwrap();
    assert!(run.steps > 0);
    assert!(run.profiling_total > 0.0);
    assert!(run.metric.is_finite());
    assert!(run.peak_mem_bytes > 0);
    assert_eq!(run.gamma_mean, 2.0); // pinned
}

#[test]
fn eval_harness_threads_per_request_params() {
    use specd::engine::Backend;
    use specd::sampling::Method;
    use specd::tables::run_method;
    use specd::workload::{make_tasks, TaskKind};

    // the harness builds every request from ctx.params — a greedy run and
    // a hot-sampled run over the same tasks come from the same engine
    // config but different SamplingParams, and must both complete
    let Some(mut ctx) = ctx(2) else { return };
    let tasks = make_tasks(&ctx.corpus, TaskKind::Summarize, 2, 9);
    ctx.params = SamplingParams::default().greedy();
    let greedy = run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 2, true).unwrap();
    ctx.params = SamplingParams::default().with_temperature(1.2).with_top_p(0.9);
    let sampled = run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 2, true).unwrap();
    assert!(greedy.steps > 0 && sampled.steps > 0);
    assert!(greedy.metric.is_finite() && sampled.metric.is_finite());
}
