//! Integration: fast table-generation paths (the simulator-only tables
//! and the harness plumbing; full measured tables run via `specd table`).

use specd::simulator::DeviceProfile;
use specd::tables::{generate, EvalContext, TableId};

#[test]
fn t3_bandwidth_table_renders() {
    let ctx = EvalContext::open_default(2).expect("run `make artifacts` first");
    let dev = DeviceProfile::by_name("a100").unwrap();
    let out = generate(TableId::T3, &ctx, dev).unwrap();
    assert!(out.contains("Table 3"));
    assert!(out.contains("GB/s"));
    // all six paper combos present
    for name in ["Whisper", "Llama2 7B", "Llama2 13B", "Qwen 7B", "Gemma 7B"] {
        assert!(out.contains(name), "missing {name} in:\n{out}");
    }
}

#[test]
fn t3_sigmoid_bandwidth_highest_per_row() {
    // parse the rendered table and check the Table-3 ordering claim
    let ctx = EvalContext::open_default(2).unwrap();
    let dev = DeviceProfile::by_name("a100").unwrap();
    let out = generate(TableId::T3, &ctx, dev).unwrap();
    let mut checked = 0;
    for line in out.lines().filter(|l| l.starts_with('|') && l.contains("GB/s")) {
        let vals: Vec<f64> = line
            .split('|')
            .filter(|c| c.contains("GB/s"))
            .filter_map(|c| c.replace("GB/s", "").trim().parse::<f64>().ok())
            .collect();
        if vals.len() == 3 {
            let (base, _exact, sigmoid) = (vals[0], vals[1], vals[2]);
            assert!(
                sigmoid > base,
                "sigmoid bandwidth must exceed baseline: {line}"
            );
            checked += 1;
        }
    }
    assert!(checked >= 5, "only {checked} rows parsed");
}

#[test]
fn eval_context_opens_and_harness_runs_one_method() {
    use specd::engine::Backend;
    use specd::sampling::Method;
    use specd::tables::run_method;
    use specd::workload::{make_tasks, TaskKind};

    let ctx = EvalContext::open_default(2).unwrap();
    let tasks = make_tasks(&ctx.corpus, TaskKind::Asr, 2, 9);
    let run = run_method(&ctx, &tasks, Method::Exact, Backend::Hlo, 2, true).unwrap();
    assert!(run.steps > 0);
    assert!(run.profiling_total > 0.0);
    assert!(run.metric.is_finite());
    assert!(run.peak_mem_bytes > 0);
    assert_eq!(run.gamma_mean, 2.0); // pinned
}
