//! Integration: TCP server round-trips over a real engine.

use std::sync::Arc;

use specd::engine::{Backend, Engine, EngineConfig, Mode};
use specd::runtime::Runtime;
use specd::sampling::Method;
use specd::server::service::Client;
use specd::server::{Server, ServerConfig};
use specd::tokenizer::Tokenizer;

fn start_server() -> Arc<Server> {
    let runtime = Arc::new(Runtime::open_default().expect("run `make artifacts` first"));
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")).unwrap();
    let engine = Engine::new(
        runtime,
        EngineConfig {
            pair: "base".into(),
            batch: 1,
            method: Method::Exact,
            backend: Backend::Hlo,
            mode: Mode::Speculative,
            gamma_init: 5,
            gamma_pinned: false,
            self_draft: false,
            seed: 3,
        },
    )
    .unwrap();
    Arc::new(
        Server::start(
            engine,
            tokenizer,
            ServerConfig {
                addr: "127.0.0.1:0".into(), // ephemeral port
            },
        )
        .unwrap(),
    )
}

#[test]
fn serves_requests_end_to_end() {
    let server = start_server();
    let addr = server.addr().to_string();
    let accept_thread = {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_forever();
        })
    };

    let mut c = Client::connect(&addr).unwrap();
    let resp = c
        .request(1, "The scheduler accepts", 16, 0.7)
        .expect("request 1");
    assert!(resp.get("error").is_none(), "{}", resp.dump());
    assert_eq!(resp.get("id").unwrap().as_i64(), Some(1));
    assert!(resp.get("tokens").unwrap().as_usize().unwrap() > 0);
    assert!(resp.get("text").unwrap().as_str().is_some());
    assert!(resp.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // second request on the same connection
    let resp2 = c.request(2, "A worker thread verifies", 8, 0.7).unwrap();
    assert_eq!(resp2.get("id").unwrap().as_i64(), Some(2));

    // a second concurrent client
    let mut c2 = Client::connect(&addr).unwrap();
    let resp3 = c2.request(9, "The profiler tracks", 8, 0.7).unwrap();
    assert_eq!(resp3.get("id").unwrap().as_i64(), Some(9));

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn malformed_requests_get_error_lines() {
    use std::io::{BufRead, BufReader, Write};
    let server = start_server();
    let addr = server.addr();
    let accept_thread = {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = server.serve_forever();
        })
    };

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = specd::util::json::parse(&line).unwrap();
    assert!(v.get("error").is_some(), "{line}");

    // and a valid one still works afterwards on the same connection
    writeln!(stream, r#"{{"id": 4, "prompt": "The batch planner", "max_new_tokens": 6}}"#)
        .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    let v2 = specd::util::json::parse(&line2).unwrap();
    assert_eq!(v2.get("id").unwrap().as_i64(), Some(4));

    server.shutdown();
    accept_thread.join().unwrap();
}
