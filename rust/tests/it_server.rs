//! Integration: TCP server round-trips over a real engine — protocol v2
//! (streaming, per-request overrides, cancellation) and the v1 shim.
//!
//! The artifact-backed tests need `make artifacts` and skip with a
//! notice when the runtime cannot be opened; the admission-queue tests
//! (bounded queue, queued-cancel, mid-flight refill, SLO metrics) run
//! over `Runtime::simulated` and are always on.

use std::sync::Arc;
use std::time::Duration;

use specd::engine::{Backend, Engine, EngineConfig, Mode, SamplingParams};
use specd::runtime::{Runtime, SimSpec};
use specd::sampling::Method;
use specd::server::{Client, Server, ServerConfig};
use specd::tokenizer::Tokenizer;
use specd::util::json::Value;

fn start_server() -> Option<Arc<Server>> {
    let runtime = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            return None;
        }
    };
    let tokenizer = Tokenizer::load(&specd::artifacts_dir().join("tokenizer.json")).unwrap();
    let engine = Engine::new(
        runtime,
        EngineConfig {
            pair: "base".into(),
            batch: 1,
            method: Method::Exact,
            backend: Backend::Hlo,
            mode: Mode::Speculative,
            gamma_init: 5,
            gamma_pinned: false,
            self_draft: false,
            pipeline: specd::engine::PipelineMode::Auto,
            pipeline_depth: 2,
            pipeline_salvage: true,
            seed: 3,
        },
    )
    .unwrap();
    Some(Arc::new(
        Server::start(
            engine,
            tokenizer,
            ServerConfig {
                addr: "127.0.0.1:0".into(), // ephemeral port
                ..Default::default()
            },
        )
        .unwrap(),
    ))
}

/// An artifact-free server over the simulated model pair: a tiny batch
/// so a single in-flight request saturates the engine and admission
/// queueing is deterministic from the client's point of view.
fn start_sim_server(batch: usize, queue_limit: usize) -> Arc<Server> {
    start_sim_server_cfg(batch, queue_limit, None, None).0
}

/// Variant with a trace recorder streaming to `trace_path` and/or a
/// load-shedding deadline for queued requests.
fn start_sim_server_cfg(
    batch: usize,
    queue_limit: usize,
    trace_path: Option<&std::path::Path>,
    shed_after: Option<Duration>,
) -> (Arc<Server>, Option<Arc<specd::trace::TraceRecorder>>) {
    let spec = SimSpec {
        vocab: 128,
        seq_len: 192,
        gmax: 8,
        batches: vec![batch],
        seed: 0xC0FFEE,
        agreement: 0.9,
        model_delay: Duration::from_micros(500),
    };
    let vocab = spec.vocab;
    let rt = Arc::new(Runtime::simulated(spec));
    let engine = Engine::new(
        rt,
        EngineConfig {
            pair: "sim".into(),
            batch,
            method: Method::Exact,
            backend: Backend::Native,
            mode: Mode::Speculative,
            gamma_init: 4,
            gamma_pinned: false,
            self_draft: false,
            pipeline: specd::engine::PipelineMode::On,
            pipeline_depth: 2,
            pipeline_salvage: true,
            seed: 13,
        },
    )
    .unwrap();
    let rec = trace_path.map(|p| {
        let r = specd::trace::TraceRecorder::to_file(engine.trace_header(), p).unwrap();
        Arc::new(r)
    });
    let chars: Vec<char> = (' '..='~').collect();
    let keep = chars.len().min(vocab - 3);
    let tok = Tokenizer::from_chars(chars[..keep].to_vec(), vocab).unwrap();
    let server = Arc::new(
        Server::start(
            engine,
            tok,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                trace: rec.clone(),
                queue_limit,
                shed_after,
            },
        )
        .unwrap(),
    );
    (server, rec)
}

fn spawn_accept(server: &Arc<Server>) -> std::thread::JoinHandle<()> {
    let server = server.clone();
    std::thread::spawn(move || {
        let _ = server.serve_forever();
    })
}

fn event(v: &Value) -> &str {
    v.get("event").and_then(Value::as_str).unwrap_or("")
}

fn finish(v: &Value) -> &str {
    v.get("finish").and_then(Value::as_str).unwrap_or("")
}

#[test]
fn serves_v1_requests_end_to_end() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    let mut c = Client::connect(&addr).unwrap();
    let resp = c
        .request(1, "The scheduler accepts", 16, 0.7)
        .expect("request 1");
    assert!(resp.get("error").is_none(), "{}", resp.dump());
    assert!(resp.get("v").is_none(), "v1 responses stay unversioned");
    assert_eq!(resp.get("id").unwrap().as_i64(), Some(1));
    assert!(resp.get("tokens").unwrap().as_usize().unwrap() > 0);
    assert!(resp.get("text").unwrap().as_str().is_some());
    assert!(resp.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // second request on the same connection
    let resp2 = c.request(2, "A worker thread verifies", 8, 0.7).unwrap();
    assert_eq!(resp2.get("id").unwrap().as_i64(), Some(2));

    // a second concurrent client
    let mut c2 = Client::connect(&addr).unwrap();
    let resp3 = c2.request(9, "The profiler tracks", 8, 0.7).unwrap();
    assert_eq!(resp3.get("id").unwrap().as_i64(), Some(9));

    server.shutdown();
    accept_thread.join().unwrap();
}

/// The protocol-v2 acceptance scenario, all against one running server:
/// stream deltas for a sampled request; run a concurrent greedy request
/// with stop sequences and a per-request γ override; cancel a third
/// mid-generation with its slot reclaimed; and a v1 one-shot request
/// still round-trips unchanged.
#[test]
fn protocol_v2_full_scenario() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    // (a) streaming sampled request
    let mut c1 = Client::connect(&addr).unwrap();
    c1.send_generate(
        1,
        "The scheduler accepts the drafted tokens",
        &SamplingParams::default()
            .with_max_new_tokens(24)
            .with_temperature(0.9)
            .with_top_p(0.9)
            .with_seed(5),
        true,
    )
    .unwrap();

    // (b) concurrent greedy request with stop sequences + γ override,
    // from a second connection (queued behind (a) on a batch-1 engine)
    let stops = ["e".to_string(), " ".to_string()];
    let mut c2 = Client::connect(&addr).unwrap();
    c2.send_generate(
        7,
        "A worker thread verifies",
        &SamplingParams::default()
            .greedy()
            .with_max_new_tokens(16)
            .with_stop(stops.to_vec())
            .pin_gamma(2),
        false,
    )
    .unwrap();

    // drain (a): deltas then done; concatenated deltas must equal the
    // final text (no stop sequences on this request, so no retraction)
    let mut streamed = String::new();
    let mut deltas = 0usize;
    let done1 = loop {
        let ev = c1.read_event().unwrap();
        match event(&ev) {
            "delta" => {
                deltas += 1;
                assert_eq!(ev.get("id").unwrap().as_i64(), Some(1));
                streamed.push_str(ev.get("text").unwrap().as_str().unwrap());
                assert!(ev.get("tokens").unwrap().as_usize().unwrap() > 0);
            }
            "done" => break ev,
            other => panic!("unexpected event {other:?}: {}", ev.dump()),
        }
    };
    assert!(deltas > 0, "streaming produced no delta events");
    assert_eq!(done1.get("id").unwrap().as_i64(), Some(1));
    let text1 = done1.get("text").unwrap().as_str().unwrap();
    assert!(
        streamed.starts_with(text1) || text1.starts_with(&streamed),
        "streamed {streamed:?} vs done {text1:?}"
    );
    assert!(done1.get("tokens").unwrap().as_usize().unwrap() <= 24);

    // drain (b): greedy + stop sequences; if a stop fired the text must
    // not contain it (the matched sequence is trimmed)
    let done2 = c2.read_event().unwrap();
    assert_eq!(event(&done2), "done", "{}", done2.dump());
    assert_eq!(done2.get("id").unwrap().as_i64(), Some(7));
    let text2 = done2.get("text").unwrap().as_str().unwrap();
    match finish(&done2) {
        "stop_seq" => {
            for s in &stops {
                assert!(!text2.contains(s.as_str()), "{text2:?} contains {s:?}");
            }
        }
        "length" => assert!(done2.get("tokens").unwrap().as_usize().unwrap() <= 16),
        other => panic!("unexpected finish {other:?}"),
    }

    // (c) cancel a third request mid-generation
    let mut c3 = Client::connect(&addr).unwrap();
    c3.send_generate(
        3,
        "The memory pool loads",
        &SamplingParams::default().with_max_new_tokens(200),
        true,
    )
    .unwrap();
    let first = c3.read_event().unwrap();
    assert_eq!(event(&first), "delta", "decode should have started");
    c3.send_cancel(3).unwrap();
    let done3 = loop {
        let ev = c3.read_event().unwrap();
        if event(&ev) != "delta" {
            break ev;
        }
    };
    assert_eq!(event(&done3), "done", "{}", done3.dump());
    assert_eq!(finish(&done3), "cancel", "{}", done3.dump());
    assert!(done3.get("tokens").unwrap().as_usize().unwrap() < 200);

    // the slot is reclaimed: the same connection serves a fresh request
    let resp4 = c3
        .request_v2(4, "The batch planner", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&resp4), "done", "{}", resp4.dump());
    assert_eq!(resp4.get("id").unwrap().as_i64(), Some(4));

    // (d) a v1 one-shot request still round-trips unchanged
    let mut c4 = Client::connect(&addr).unwrap();
    let v1 = c4.request(9, "The profiler tracks", 8, 0.7).unwrap();
    assert!(v1.get("error").is_none(), "{}", v1.dump());
    assert!(v1.get("v").is_none());
    assert!(v1.get("event").is_none());
    assert_eq!(v1.get("id").unwrap().as_i64(), Some(9));
    assert!(v1.get("tokens").unwrap().as_usize().unwrap() > 0);

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn malformed_requests_get_error_lines() {
    use std::io::{BufRead, BufReader, Write};
    let Some(server) = start_server() else { return };
    let addr = server.addr();
    let accept_thread = spawn_accept(&server);

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = specd::util::json::parse(&line).unwrap();
    assert!(v.get("error").is_some(), "{line}");
    assert_eq!(v.get("code").unwrap().as_str(), Some("parse"));

    // and a valid one still works afterwards on the same connection
    writeln!(stream, r#"{{"id": 4, "prompt": "The batch planner", "max_new_tokens": 6}}"#)
        .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    let v2 = specd::util::json::parse(&line2).unwrap();
    assert_eq!(v2.get("id").unwrap().as_i64(), Some(4));

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn protocol_error_paths_over_the_wire() {
    use std::io::{BufRead, BufReader, Write};
    let Some(server) = start_server() else { return };
    let addr = server.addr();
    let accept_thread = spawn_accept(&server);

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // v2-dialect (and dialect-unknown) failures: structured error events
    let mut expect_code = |line: &str, code: &str| {
        writeln!(stream, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let v = specd::util::json::parse(&resp).unwrap();
        assert_eq!(v.get("event").and_then(Value::as_str), Some("error"), "{resp}");
        assert_eq!(v.get("code").and_then(Value::as_str), Some(code), "{resp}");
        assert!(v.get("error").is_some(), "{resp}");
    };
    expect_code("this is not json", "parse");
    expect_code(r#"{"v":2,"op":"noop","id":1}"#, "unknown_op");
    expect_code(r#"{"v":9,"id":1,"prompt":"x"}"#, "unsupported_version");
    expect_code(r#"{"v":2,"id":1,"prompt":"x","params":{"nucleus":0.9}}"#, "invalid_params");
    expect_code(r#"{"v":2,"id":1,"prompt":"x","params":{"gamma":2.5}}"#, "invalid_params");
    expect_code(r#"{"v":2,"id":1,"prompt":"x","Stream":true}"#, "bad_request");
    // cancel for an id this connection never sent
    expect_code(r#"{"v":2,"op":"cancel","id":55}"#, "unknown_id");

    // v1-dialect failures: v1-shaped {"id":…,"error":…} lines (no event)
    let mut expect_v1_error = |line: &str| {
        writeln!(stream, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let v = specd::util::json::parse(&resp).unwrap();
        assert!(v.get("event").is_none(), "{resp}");
        assert!(v.get("v").is_none(), "{resp}");
        assert!(v.get("error").unwrap().as_str().is_some(), "{resp}");
    };
    expect_v1_error(r#"{"prompt": "missing id"}"#);
    expect_v1_error(r#"{"id": 1}"#);
    expect_v1_error(r#"{"id": "one", "prompt": "x"}"#);
    expect_v1_error(r#"{"id": 1, "prompt": "x", "max_new_tokens": "lots"}"#);
    expect_v1_error(r#"{"id":1,"prompt":"x","temperature":-0.5}"#);
    expect_v1_error(r#"{"id":1,"prompt":"x","max_new_tokens":0}"#);

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn admission_rejects_overlong_prompts_with_structured_error() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    let mut c = Client::connect(&addr).unwrap();
    // far beyond any model context S — rejected at admission instead of
    // decoding garbage or finishing with "context" immediately
    let huge = "a ".repeat(50_000);
    let resp = c
        .request_v2(1, &huge, &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&resp), "error", "{}", resp.dump());
    assert_eq!(resp.get("code").unwrap().as_str(), Some("rejected"));
    assert!(resp
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("context"));

    // the connection and server stay healthy afterwards
    let ok = c
        .request_v2(2, "short prompt", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&ok), "done", "{}", ok.dump());

    // unsupported per-request gamma override is also rejected up front
    let resp = c
        .request_v2(
            3,
            "short",
            &SamplingParams::default().with_max_new_tokens(4).with_gamma(10_000),
        )
        .unwrap();
    assert_eq!(event(&resp), "error", "{}", resp.dump());
    assert_eq!(resp.get("code").unwrap().as_str(), Some("rejected"));

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn queued_request_cancel_removes_pending_entry() {
    let server = start_sim_server(1, 8);
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    // (a) occupy the single slot and confirm decode started
    let mut a = Client::connect(&addr).unwrap();
    a.send_generate(
        1,
        "the scheduler accepts the drafted tokens",
        &SamplingParams::default().with_max_new_tokens(150).with_seed(1),
        true,
    )
    .unwrap();
    let first = a.read_event().unwrap();
    assert_eq!(event(&first), "delta", "{}", first.dump());

    // (b) with the slot held, a second request necessarily sits in the
    // server's admission queue; cancelling it must remove the pending
    // entry and answer directly — the engine never sees the request
    let mut b = Client::connect(&addr).unwrap();
    b.send_generate(
        2,
        "a worker thread verifies",
        &SamplingParams::default().with_max_new_tokens(8),
        false,
    )
    .unwrap();
    b.send_cancel(2).unwrap();
    let done = b.read_event().unwrap();
    assert_eq!(event(&done), "done", "{}", done.dump());
    assert_eq!(finish(&done), "cancel", "{}", done.dump());
    assert_eq!(done.get("tokens").unwrap().as_usize(), Some(0));
    // queued-cancel done events carry the SLO block too
    assert!(done.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(done.get("queue_depth").is_some(), "{}", done.dump());
    assert!(done.get("latency_percentiles_ms").is_some(), "{}", done.dump());

    // (a) is unaffected and still finishes cleanly
    a.send_cancel(1).unwrap();
    let done_a = loop {
        let ev = a.read_event().unwrap();
        if event(&ev) != "delta" {
            break ev;
        }
    };
    assert_eq!(event(&done_a), "done", "{}", done_a.dump());
    // the pipelined engine surfaces its scheduler counters on done
    let p = done_a
        .get("pipeline")
        .unwrap_or_else(|| panic!("no pipeline block: {}", done_a.dump()));
    assert!(p.get("depth").unwrap().as_usize().unwrap() >= 1);
    assert!(p.get("slots_salvaged").is_some(), "{}", done_a.dump());
    assert!(p.get("slots_redone").is_some(), "{}", done_a.dump());
    assert!(p.get("effective_hit_rate").unwrap().as_f64().is_some());

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn bounded_queue_rejects_with_queue_full_and_refills_mid_flight() {
    let server = start_sim_server(1, 1);
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    // saturate: one decoding request plus one queued request
    let mut a = Client::connect(&addr).unwrap();
    a.send_generate(
        1,
        "the scheduler accepts the drafted tokens",
        &SamplingParams::default().with_max_new_tokens(150).with_seed(2),
        true,
    )
    .unwrap();
    let first = a.read_event().unwrap();
    assert_eq!(event(&first), "delta", "{}", first.dump());
    // both probes share one connection: its reader hands them to the
    // engine thread in order, so "2 queued, then 3 rejected" is
    // deterministic (across connections the arrival order would race)
    let mut b = Client::connect(&addr).unwrap();
    b.send_generate(
        2,
        "a worker thread verifies",
        &SamplingParams::default().with_max_new_tokens(4),
        false,
    )
    .unwrap();
    b.send_generate(
        3,
        "the memory pool loads",
        &SamplingParams::default().with_max_new_tokens(4),
        false,
    )
    .unwrap();

    // the queue is at its bound — request 3 is load-shed with a
    // structured error, not silently stalled
    let err = b.read_event().unwrap();
    assert_eq!(event(&err), "error", "{}", err.dump());
    assert_eq!(err.get("id").unwrap().as_i64(), Some(3));
    assert_eq!(err.get("code").unwrap().as_str(), Some("queue_full"));

    // free the slot: the queued request refills mid-flight and its done
    // event reports the time it spent waiting
    a.send_cancel(1).unwrap();
    let done_a = loop {
        let ev = a.read_event().unwrap();
        if event(&ev) != "delta" {
            break ev;
        }
    };
    assert_eq!(event(&done_a), "done", "{}", done_a.dump());
    let done_b = b.read_event().unwrap();
    assert_eq!(event(&done_b), "done", "{}", done_b.dump());
    assert_eq!(done_b.get("id").unwrap().as_i64(), Some(2));
    assert!(done_b.get("tokens").unwrap().as_usize().unwrap() > 0);
    assert!(done_b.get("queue_ms").unwrap().as_f64().unwrap() > 0.0);

    // the connection whose request was shed stays healthy and can retry
    let retry = b
        .request_v2(4, "retry", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&retry), "done", "{}", retry.dump());

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn live_record_toggle_mid_stream_with_active_trace_file() {
    use specd::server::protocol::render_record;
    let path = std::env::temp_dir()
        .join(format!("specd_it_server_toggle_{}.sptr", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let (server, rec) = start_sim_server_cfg(1, 8, Some(&path), None);
    let rec = rec.expect("recorder attached");
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    // a long streaming decode holds the slot while the gate flips
    let mut c = Client::connect(&addr).unwrap();
    c.send_generate(
        1,
        "the scheduler accepts the drafted tokens",
        &SamplingParams::default().with_max_new_tokens(150).with_seed(5),
        true,
    )
    .unwrap();
    let first = c.read_event().unwrap();
    assert_eq!(event(&first), "delta", "{}", first.dump());

    // flip off, then back on, mid-stream: each flip is acked in order
    // with the resulting gate state, and deltas keep flowing around the
    // acks on the same connection
    let ack_after = |c: &mut Client| loop {
        let ev = c.read_event().unwrap();
        match event(&ev) {
            "record" => break ev,
            "delta" => {}
            other => panic!("unexpected event {other:?}: {}", ev.dump()),
        }
    };
    c.send_line(&render_record(900, false)).unwrap();
    let ack = ack_after(&mut c);
    assert_eq!(ack.get("id").unwrap().as_i64(), Some(900));
    assert_eq!(ack.get("enabled").unwrap().as_bool(), Some(false));
    assert!(!rec.is_enabled(), "gate still on after the off ack");
    c.send_line(&render_record(901, true)).unwrap();
    let ack = ack_after(&mut c);
    assert_eq!(ack.get("id").unwrap().as_i64(), Some(901));
    assert_eq!(ack.get("enabled").unwrap().as_bool(), Some(true));
    assert!(rec.is_enabled(), "gate still off after the on ack");

    // the interrupted stream still reaches its terminal, and the
    // connection serves another request afterwards
    c.send_cancel(1).unwrap();
    let done = loop {
        let ev = c.read_event().unwrap();
        if event(&ev) != "delta" {
            break ev;
        }
    };
    assert_eq!(event(&done), "done", "{}", done.dump());
    assert_eq!(finish(&done), "cancel", "{}", done.dump());
    let ok = c
        .request_v2(2, "still healthy", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&ok), "done", "{}", ok.dump());

    // shutdown joins the engine thread, so the file is complete after a
    // flush — and must decode as a trace that recorded the admit before
    // the gap (the gate was on when request 1 was admitted)
    server.shutdown();
    accept_thread.join().unwrap();
    rec.flush().unwrap();
    let trace = specd::trace::format::load(&path).unwrap();
    let admits = trace
        .events
        .iter()
        .filter(|e| matches!(e, specd::trace::TraceEvent::Admit(_)))
        .count();
    assert!(admits >= 1, "trace file lost the pre-toggle admit");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn record_toggle_without_recorder_is_a_structured_error() {
    use specd::server::protocol::render_record;
    let server = start_sim_server(1, 4);
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    let mut c = Client::connect(&addr).unwrap();
    c.send_line(&render_record(5, true)).unwrap();
    let err = c.read_event().unwrap();
    assert_eq!(event(&err), "error", "{}", err.dump());
    assert_eq!(err.get("code").unwrap().as_str(), Some("no_recorder"));
    assert_eq!(err.get("id").unwrap().as_i64(), Some(5));

    // the connection stays usable after the refused toggle
    let ok = c
        .request_v2(1, "still healthy", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&ok), "done", "{}", ok.dump());

    server.shutdown();
    accept_thread.join().unwrap();
}

#[test]
fn shed_deadline_racing_queued_cancel_yields_exactly_one_terminal() {
    let (server, _) = start_sim_server_cfg(1, 8, None, Some(Duration::from_millis(40)));
    let addr = server.addr().to_string();
    let accept_thread = spawn_accept(&server);

    // hold the single slot with a long decode
    let mut a = Client::connect(&addr).unwrap();
    a.send_generate(
        1,
        "the scheduler accepts the drafted tokens",
        &SamplingParams::default().with_max_new_tokens(150).with_seed(3),
        true,
    )
    .unwrap();
    let first = a.read_event().unwrap();
    assert_eq!(event(&first), "delta", "{}", first.dump());

    // queue a second request, then cancel it right at the shed
    // deadline. Any interleaving is legal — shed first, cancel first,
    // or (if the slot freed early) a mid-decode cancel — but request 2
    // must reach EXACTLY one terminal event with a correct code
    let mut b = Client::connect(&addr).unwrap();
    b.send_generate(
        2,
        "a worker thread verifies",
        &SamplingParams::default().with_max_new_tokens(8),
        false,
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(40));
    b.send_cancel(2).unwrap();

    let term = b.read_event().unwrap();
    assert_eq!(term.get("id").unwrap().as_i64(), Some(2), "{}", term.dump());
    match event(&term) {
        "done" => {
            // cancel won (queued or mid-decode) or the decode finished
            // before the cancel landed; all carry the SLO block
            assert!(matches!(finish(&term), "cancel" | "length"), "{}", term.dump());
            assert!(term.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(term.get("latency_percentiles_ms").is_some(), "{}", term.dump());
        }
        "error" => {
            // shed won: the message carries the server's own wait
            // accounting, which must honor the configured deadline
            assert_eq!(term.get("code").unwrap().as_str(), Some("shed"), "{}", term.dump());
            let msg = term.get("error").unwrap().as_str().unwrap();
            let nums: Vec<u64> = msg
                .split(|ch: char| !ch.is_ascii_digit())
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.parse().ok())
                .collect();
            assert_eq!(nums.len(), 2, "shed message should carry waited+deadline: {msg}");
            assert!(nums[0] >= nums[1], "shed before its deadline: {msg}");
        }
        other => panic!("unexpected terminal {other:?}: {}", term.dump()),
    }

    // free the slot so follow-up work can decode un-shed
    a.send_cancel(1).unwrap();
    let done_a = loop {
        let ev = a.read_event().unwrap();
        if event(&ev) != "delta" {
            break ev;
        }
    };
    assert_eq!(event(&done_a), "done", "{}", done_a.dump());

    // exactly-one-terminal, observed: the next event on b's connection
    // is the fresh request's done — not a second terminal for id 2
    let follow = b
        .request_v2(3, "follow up", &SamplingParams::default().with_max_new_tokens(4))
        .unwrap();
    assert_eq!(event(&follow), "done", "{}", follow.dump());
    assert_eq!(follow.get("id").unwrap().as_i64(), Some(3));

    server.shutdown();
    accept_thread.join().unwrap();
}
