//! Integration: PJRT runtime ↔ AOT artifacts ↔ pure-rust oracle.
//!
//! Requires `make artifacts` (or `make quick-artifacts`). The HLO verify
//! executables are cross-checked against `specd::sampling` on the same
//! inputs — `baseline`/`exact` must agree with the oracle decision-for-
//! decision, which triangulates all three implementations (jnp graph,
//! pallas kernel, rust). Tests skip with a notice when the runtime
//! cannot be opened.

use std::sync::Arc;

use specd::runtime::{HostTensor, Runtime};
use specd::sampling::{self, Method};
use specd::util::rng::Pcg32;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::open_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e:#})");
            None
        }
    }
}

fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

struct VerifyCase {
    b: usize,
    g: usize,
    v: usize,
    z_p: Vec<f32>,
    z_q: Vec<f32>,
    draft: Vec<i32>,
    u_acc: Vec<f32>,
    u_res: Vec<f32>,
    u_bonus: Vec<f32>,
}

fn make_case(rng: &mut Pcg32, b: usize, g: usize, v: usize) -> VerifyCase {
    VerifyCase {
        b,
        g,
        v,
        z_p: randn(rng, b * (g + 1) * v, 3.0),
        z_q: randn(rng, b * g * v, 3.0),
        draft: (0..b * g).map(|_| rng.below(v as u32) as i32).collect(),
        u_acc: (0..b * g).map(|_| rng.uniform_f32()).collect(),
        u_res: (0..b).map(|_| rng.uniform_f32()).collect(),
        u_bonus: (0..b).map(|_| rng.uniform_f32()).collect(),
    }
}

fn run_hlo(
    rt: &Runtime,
    method: &str,
    case: &VerifyCase,
    alpha_beta: Option<(f32, f32)>,
) -> (Vec<i32>, Vec<i32>) {
    let exe = rt
        .load_verify(method, case.b, case.g, case.v)
        .unwrap_or_else(|e| panic!("loading verify_{method}: {e:#}"));
    let mut inputs = vec![
        HostTensor::f32(&[case.b, case.g + 1, case.v], case.z_p.clone()),
        HostTensor::f32(&[case.b, case.g, case.v], case.z_q.clone()),
        HostTensor::i32(&[case.b, case.g], case.draft.clone()),
        HostTensor::f32(&[case.b, case.g], case.u_acc.clone()),
        HostTensor::f32(&[case.b], case.u_res.clone()),
        HostTensor::f32(&[case.b], case.u_bonus.clone()),
    ];
    if let Some((a, b)) = alpha_beta {
        inputs.push(HostTensor::f32(&[2], vec![a, b]));
    }
    let out = exe.run(&inputs).expect("execute");
    (
        out[0].as_i32().unwrap().to_vec(),
        out[1].as_i32().unwrap().to_vec(),
    )
}

fn run_native(case: &VerifyCase, method: Method) -> (Vec<i32>, Vec<i32>) {
    sampling::verify::spec_step_batch(
        &case.z_p,
        &case.z_q,
        case.b,
        case.g,
        case.v,
        &case.draft,
        &case.u_acc,
        &case.u_res,
        &case.u_bonus,
        &vec![method; case.b],
        None,
    )
}

#[test]
fn hlo_exact_matches_native_oracle() {
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    let mut rng = Pcg32::seeded(11);
    for trial in 0..8 {
        let case = make_case(&mut rng, 1, 5, v);
        let (hlo_len, hlo_tok) = run_hlo(&rt, "exact", &case, None);
        let (nat_len, nat_tok) = run_native(&case, Method::Exact);
        assert_eq!(hlo_len, nat_len, "trial {trial} accept_len");
        assert_eq!(hlo_tok, nat_tok, "trial {trial} tokens");
    }
}

#[test]
fn hlo_baseline_and_exact_bit_identical() {
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    let mut rng = Pcg32::seeded(12);
    for g in [1usize, 2, 5] {
        for _ in 0..4 {
            let case = make_case(&mut rng, 1, g, v);
            let a = run_hlo(&rt, "baseline", &case, None);
            let b = run_hlo(&rt, "exact", &case, None);
            assert_eq!(a, b, "γ={g}");
        }
    }
}

#[test]
fn hlo_sigmoid_matches_native_sigmoid() {
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    let mut rng = Pcg32::seeded(13);
    for (alpha, beta) in [(-1e3f32, 1e3f32), (-1e4, 1e4)] {
        let case = make_case(&mut rng, 1, 5, v);
        let (hlo_len, hlo_tok) = run_hlo(&rt, "sigmoid", &case, Some((alpha, beta)));
        let (nat_len, nat_tok) = run_native(&case, Method::sigmoid(alpha, beta));
        assert_eq!(hlo_len, nat_len, "alpha={alpha}");
        assert_eq!(hlo_tok, nat_tok, "alpha={alpha}");
    }
}

#[test]
fn hlo_heterogeneous_methods_dispatch_per_row() {
    // the grouped HLO dispatch (one artifact call per distinct method,
    // selective per-row copy-back) must reproduce the native oracle's
    // per-row decisions on a mixed exact/sigmoid batch
    use specd::engine::{Backend, Verifier, VerifyInputs};
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    // find a batched verify shape both methods can serve
    let mut found = None;
    for b in 2..=8 {
        let ge = rt.manifest.verify_gammas("exact", b, v);
        let gs = rt.manifest.verify_gammas("sigmoid", b, v);
        if let Some(&g) = ge.iter().find(|g| gs.contains(g)) {
            found = Some((b, g));
            break;
        }
    }
    let Some((b, g)) = found else {
        eprintln!("skipping: no batch > 1 verify artifacts shared by exact+sigmoid");
        return;
    };
    let mut rng = Pcg32::seeded(16);
    let case = make_case(&mut rng, b, g, v);
    let methods: Vec<Method> = (0..b)
        .map(|i| {
            if i % 2 == 0 {
                Method::Exact
            } else {
                Method::sigmoid(-1e3, 1e3)
            }
        })
        .collect();
    let mut verifier = Verifier::new(rt.clone(), Method::Exact, Backend::Hlo, b, v);
    let (out, _secs) = verifier
        .verify(
            g,
            &methods,
            &VerifyInputs {
                z_p: &case.z_p,
                z_q: &case.z_q,
                draft: &case.draft,
                u_acc: &case.u_acc,
                u_res: &case.u_res,
                u_bonus: &case.u_bonus,
            },
        )
        .expect("hlo heterogeneous verify");
    let (nat_len, nat_tok) = sampling::verify::spec_step_batch(
        &case.z_p, &case.z_q, b, g, v, &case.draft, &case.u_acc, &case.u_res,
        &case.u_bonus, &methods, None,
    );
    assert_eq!(out.accept_len, nat_len, "per-row accept lengths");
    assert_eq!(out.out_tokens, nat_tok, "per-row emitted tokens");
}

#[test]
fn verify_output_contract_holds() {
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    let mut rng = Pcg32::seeded(14);
    let case = make_case(&mut rng, 1, 5, v);
    let (len, toks) = run_hlo(&rt, "exact", &case, None);
    let alen = len[0] as usize;
    assert!(alen <= 5);
    // emitted tokens valid, padding is -1
    for (i, &t) in toks.iter().enumerate() {
        if i <= alen {
            assert!((0..v as i32).contains(&t), "slot {i} = {t}");
        } else {
            assert_eq!(t, -1, "slot {i}");
        }
    }
    // accepted prefix equals the drafts
    assert_eq!(&toks[..alen], &case.draft[..alen]);
}

#[test]
fn draft_step_greedy_is_argmax_and_deterministic() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let (s, _v) = (m.seq_len, m.vocab_size);
    let exe = rt.load_model("draft_step", "base", 1).expect("draft_step");
    let mut tokens = vec![0i32; s];
    for (i, t) in tokens.iter_mut().enumerate().take(12) {
        *t = 3 + (i as i32 % 40);
    }
    let inputs = [
        HostTensor::i32(&[1, s], tokens.clone()),
        HostTensor::i32(&[1], vec![12]),
        HostTensor::f32(&[1], vec![0.3]),
        HostTensor::f32(&[1], vec![0.0]), // temp 0 => greedy
    ];
    let out1 = exe.run(&inputs).unwrap();
    let out2 = exe.run(&inputs).unwrap();
    let tok1 = out1[0].as_i32().unwrap()[0];
    let logits = out1[1].as_f32().unwrap();
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    assert_eq!(tok1, argmax, "greedy must be argmax");
    assert_eq!(out2[0].as_i32().unwrap()[0], tok1, "determinism");
}

#[test]
fn target_score_window_is_shifted_next_logits() {
    // target_score's last row at lens L must equal target_step's logits at
    // the same prefix (both are the next-token distribution at position L).
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let (s, v, w) = (m.seq_len, m.vocab_size, m.gmax + 1);
    let score = rt.load_model("target_score", "base", 1).unwrap();
    let step = rt.load_model("target_step", "base", 1).unwrap();
    let mut tokens = vec![0i32; s];
    for (i, t) in tokens.iter_mut().enumerate().take(30) {
        *t = 3 + ((i * 7) as i32 % 50);
    }
    let lens = vec![30i32];
    let score_out = score
        .run(&[
            HostTensor::i32(&[1, s], tokens.clone()),
            HostTensor::i32(&[1], lens.clone()),
        ])
        .unwrap();
    let win = score_out[0].as_f32().unwrap(); // (1, w, v)
    let step_out = step
        .run(&[
            HostTensor::i32(&[1, s], tokens.clone()),
            HostTensor::i32(&[1], lens),
            HostTensor::f32(&[1], vec![0.5]),
            HostTensor::f32(&[1], vec![0.0]),
        ])
        .unwrap();
    let next = step_out[1].as_f32().unwrap(); // (1, v)
    let last_row = &win[(w - 1) * v..w * v];
    for (a, b) in last_row.iter().zip(next) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn literal_round_trip_through_tensors() {
    let Some(_rt) = runtime() else { return }; // ensures the PJRT plugin is loadable
    let t = HostTensor::f32(&[2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, -1e7]);
    let lit = t.to_literal().unwrap();
    let back = HostTensor::from_literal(&lit).unwrap();
    assert_eq!(t, back);
    let t = HostTensor::i32(&[4], vec![-1, 0, 7, i32::MAX]);
    let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
    assert_eq!(t, back);
}

#[test]
fn executable_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model("draft_step", "base", 1).unwrap();
    let bad = [
        HostTensor::i32(&[1, 4], vec![0; 4]), // wrong S
        HostTensor::i32(&[1], vec![1]),
        HostTensor::f32(&[1], vec![0.0]),
        HostTensor::f32(&[1], vec![1.0]),
    ];
    assert!(exe.run(&bad).is_err());
    // wrong arity
    assert!(exe.run(&bad[..2]).is_err());
}

#[test]
fn profiler_accumulates_exec_scopes() {
    let Some(rt) = runtime() else { return };
    let v = rt.manifest.vocab_size;
    let mut rng = Pcg32::seeded(15);
    let case = make_case(&mut rng, 1, 1, v);
    rt.profiler.reset();
    let _ = run_hlo(&rt, "exact", &case, None);
    let _ = run_hlo(&rt, "exact", &case, None);
    let stat = rt.profiler.get(&format!("exec/verify_exact_b1_g1_v{v}"));
    assert_eq!(stat.calls, 2);
    assert!(stat.total.as_nanos() > 0);
    let agg = rt.profiler.get("exec_kind/verify/exact");
    assert_eq!(agg.calls, 2);
}
