"""L2: char-level transformer LMs (draft + target) in pure JAX.

Substitutes for the paper's Whisper/Llama2/Qwen/Gemma pairs (see
DESIGN.md §3): speculative sampling only consumes target/draft logits, so
two decoder-only transformers of different depth/width trained on the same
corpus reproduce the acceptance dynamics that drive the paper's numbers.

Pure-function style (params are pytrees of jnp arrays) so `aot.py` can
close over trained params and bake them into the lowered HLO as constants.
Architecture follows the Llama2 recipe scaled down: RMSNorm pre-norm,
SwiGLU MLP, learned absolute positions (RoPE is overkill at S=256).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 128
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 256
    dtype: str = "float32"

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (checked by tests against the pytree)."""
        c = self
        emb = c.vocab_size * c.d_model + c.max_seq * c.d_model
        attn = 4 * c.d_model * c.d_model
        mlp = 3 * c.d_model * c.d_ff
        norms = 2 * c.d_model
        head = c.d_model * c.vocab_size + c.d_model  # lm head + final norm
        return emb + c.n_layers * (attn + mlp + norms) + head


# Preset pairs mirroring the paper's draft/target families (scaled down).
# Names echo the roles in Table 1; sizes keep build-time training cheap.
PRESETS: Dict[str, ModelConfig] = {
    # "whisper-small.en"-role target / "distil-small.en"-role draft (ASR task)
    "target-base": ModelConfig(d_model=128, n_layers=4, n_heads=4, d_ff=512),
    "draft-base": ModelConfig(d_model=64, n_layers=2, n_heads=2, d_ff=256),
    # larger pair ("large-v2" / "distil-large-v2"-role) for the second row group
    "target-large": ModelConfig(d_model=192, n_layers=6, n_heads=6, d_ff=768),
    "draft-large": ModelConfig(d_model=96, n_layers=3, n_heads=3, d_ff=384),
}


def init_params(cfg: ModelConfig, seed: int) -> dict:
    """Initialise a parameter pytree (numpy RNG: reproducible, cheap)."""
    rng = np.random.RandomState(seed)
    dt = np.float32

    def dense(shape, scale=None):
        fan_in = shape[0]
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        return jnp.asarray(rng.normal(0.0, scale, size=shape).astype(dt))

    params = {
        "tok_emb": dense((cfg.vocab_size, cfg.d_model), scale=0.02),
        "pos_emb": dense((cfg.max_seq, cfg.d_model), scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense((cfg.d_model, cfg.vocab_size)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "attn_norm": jnp.ones((cfg.d_model,), dt),
                "wq": dense((cfg.d_model, cfg.d_model)),
                "wk": dense((cfg.d_model, cfg.d_model)),
                "wv": dense((cfg.d_model, cfg.d_model)),
                "wo": dense((cfg.d_model, cfg.d_model)),
                "mlp_norm": jnp.ones((cfg.d_model,), dt),
                "w_gate": dense((cfg.d_model, cfg.d_ff)),
                "w_up": dense((cfg.d_model, cfg.d_ff)),
                "w_down": dense((cfg.d_ff, cfg.d_model)),
            }
        )
    return params


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def attention(layer: dict, x: jnp.ndarray, cfg: ModelConfig,
              pad_mask: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ layer["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ layer["wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ layer["wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # (b,h,s,s)
    causal = jnp.tril(jnp.ones((s, s), bool))
    mask = causal[None, None, :, :] & pad_mask[:, None, None, :]
    scores = jnp.where(mask, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ layer["wo"]


def mlp(layer: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ layer["w_gate"])
    return (gate * (x @ layer["w_up"])) @ layer["w_down"]


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            lens: jnp.ndarray, num_layers: int | None = None) -> jnp.ndarray:
    """Full-sequence forward.

    tokens: i32 (B, S) — padded with 0 beyond lens[b].
    lens:   i32 (B,)   — valid prefix length per row.
    num_layers: run only the first k transformer blocks (still through the
    final norm + lm head) — the layer-skipping used by self-speculative
    drafting (Zhang et al. 2024, cited in the paper's §A.7).
    returns logits f32 (B, S, V); positions >= lens are garbage (masked
    attention keeps positions < lens causal + pad-invariant).
    """
    b, s = tokens.shape
    positions = jnp.arange(s)
    pad_mask = positions[None, :] < lens[:, None]  # (B, S) keys validity
    x = params["tok_emb"][tokens] + params["pos_emb"][positions][None, :, :]
    layers = params["layers"] if num_layers is None else params["layers"][:num_layers]
    for layer in layers:
        x = x + attention(layer, rms_norm(x, layer["attn_norm"]), cfg, pad_mask)
        x = x + mlp(layer, rms_norm(x, layer["mlp_norm"]))
    x = rms_norm(x, params["final_norm"])
    return x @ params["lm_head"]


def logits_at(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
              lens: jnp.ndarray, last_k: int) -> jnp.ndarray:
    """Logits at the last `last_k` valid positions: (B, last_k, V).

    Row b, slot j holds the logits at sequence position lens[b]-last_k+j —
    i.e. the distribution for the token at position lens[b]-last_k+j+1.
    Slots that would index before position 0 are clamped (callers only read
    slots that exist).
    """
    full = forward(params, cfg, tokens, lens)  # (B, S, V)
    b = tokens.shape[0]
    offs = jnp.arange(last_k) - last_k  # [-k .. -1]
    idx = jnp.maximum(lens[:, None] + offs[None, :], 0)  # (B, k)
    return jnp.take_along_axis(full, idx[:, :, None], axis=1)


def next_logits(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                lens: jnp.ndarray) -> jnp.ndarray:
    """Next-token logits at the end of each row's prefix: (B, V)."""
    return logits_at(params, cfg, tokens, lens, 1)[:, 0, :]


def loss_fn(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            lens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-char cross entropy over valid positions."""
    logits = forward(params, cfg, tokens, lens)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, :, None], axis=-1)[:, :, 0]
    valid = (jnp.arange(tokens.shape[1] - 1)[None, :] + 1) < lens[:, None]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
