"""L2: full speculative-sampling verification graphs.

One fused graph per (method, B, G, V): takes target/draft logits, the
drafted tokens, and externally-supplied uniforms (the rust coordinator owns
the RNG — PCG64 — so the whole stack is deterministic given a seed) and
returns the accepted length plus the emitted tokens, i.e. everything the
L3 hot path needs from one PJRT call.

Methods (§3.2):
  baseline — unfused reference mirroring the HF transformers implementation:
             full softmax on both logit tensors, gather, ratio, residual,
             normalised resampling. No Pallas.
  exact    — softmax (still required: the kernel consumes probabilities,
             like the paper's precomputed p/q inputs) + the fused Pallas
             tile kernel for tau/a/b. Bit-identical outputs to baseline.
  sigmoid  — the fused Pallas sigmoid-approximation kernel on raw logits;
             softmax never happens. alpha/beta are runtime inputs.

Verification semantics (shared tail, Eq. 1-3):
  accept_c   = u_acc[:, c] <= tau_c(draft_c)            c = 0..G-1
  accept_len = length of the leading run of accepts
  on first rejection at position r: emit x ~ max_norm(p_r - q_r) via
  inverse CDF with u_res (no division: threshold u*b on the raw cumsum)
  on all-accept: emit a bonus token x ~ p_G via inverse CDF with u_bonus
  out_tokens[:, :accept_len] = draft tokens, out_tokens[:, accept_len] =
  resampled/bonus token, remaining slots = -1.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.spec_verify import (
    DEFAULT_TILE,
    verify_tiles_exact,
    verify_tiles_sigmoid,
)

METHODS = ("baseline", "exact", "sigmoid", "sigmoid16")


def _finish(tau_full, a, b, bonus_weights, draft, u_acc, u_res, u_bonus):
    """Shared acceptance/resample/bonus tail.

    tau_full: (B, G, V); a: (B, G, V); b: (B, G); bonus_weights: (B, V)
    draft: i32 (B, G); u_*: f32 uniforms.
    """
    bsz, g = draft.shape
    tau_sel = jnp.take_along_axis(tau_full, draft[:, :, None], axis=-1)[:, :, 0]
    accept = (u_acc <= tau_sel).astype(jnp.int32)  # (B, G)
    run = jnp.cumprod(accept, axis=1)
    accept_len = jnp.sum(run, axis=1)  # (B,)

    # Residual resampling at the first rejected position (clamped: unused
    # when all tokens were accepted). Gather one row, then a single cumsum —
    # cheaper than the naive all-positions CDF (see DESIGN.md §9 item 2).
    rej = jnp.minimum(accept_len, g - 1)
    a_rej = jnp.take_along_axis(a, rej[:, None, None], axis=1)[:, 0, :]  # (B,V)
    res_tok = ref.inverse_cdf_sample(a_rej, u_res)

    bonus_tok = ref.inverse_cdf_sample(bonus_weights, u_bonus)
    next_tok = jnp.where(accept_len == g, bonus_tok, res_tok).astype(jnp.int32)

    idx = jnp.arange(g + 1)[None, :]  # (1, G+1)
    draft_pad = jnp.concatenate([draft, jnp.zeros((bsz, 1), jnp.int32)], axis=1)
    out = jnp.where(idx < accept_len[:, None], draft_pad, -1)
    out = jnp.where(idx == accept_len[:, None], next_tok[:, None], out)
    return accept_len.astype(jnp.int32), out.astype(jnp.int32), tau_sel


def make_verify_fn(
    method: str,
    tile: int = DEFAULT_TILE,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Callable:
    """Build the verification graph for `method`.

    Returned signature (sigmoid takes a trailing (2,) alpha_beta input):
      fn(z_p (B,G+1,V), z_q (B,G,V), draft i32(B,G),
         u_acc (B,G), u_res (B,), u_bonus (B,) [, alpha_beta (2,)])
        -> (accept_len i32(B,), out_tokens i32(B,G+1), tau_sel f32(B,G))
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")

    if method == "baseline":

        def fn(z_p, z_q, draft, u_acc, u_res, u_bonus):
            # Unfused: two full stable softmaxes (each a max + a sum
            # reduction over V), then the straight-line Eq. 1-3 math.
            p = ref.softmax(z_p)  # (B, G+1, V)
            q = ref.softmax(z_q)  # (B, G, V)
            tau, a, b = ref.ref_verify(p[:, :-1, :], q)
            return _finish(tau, a, b, p[:, -1, :], draft, u_acc, u_res, u_bonus)

        return fn

    if method == "exact":

        def fn(z_p, z_q, draft, u_acc, u_res, u_bonus):
            p = ref.softmax(z_p)
            q = ref.softmax(z_q)
            if use_pallas:
                tau, a, b = verify_tiles_exact(
                    p[:, :-1, :], q, tile=tile, interpret=interpret
                )
            else:
                tau, a, b = ref.ref_verify(p[:, :-1, :], q)
            return _finish(tau, a, b, p[:, -1, :], draft, u_acc, u_res, u_bonus)

        return fn

    if method == "sigmoid":

        def fn(z_p, z_q, draft, u_acc, u_res, u_bonus, alpha_beta):
            if use_pallas:
                tau, a, b = verify_tiles_sigmoid(
                    z_p[:, :-1, :], z_q, alpha_beta, tile=tile, interpret=interpret
                )
            else:
                tau, a, b = ref.ref_verify_sigmoid(
                    z_p[:, :-1, :], z_q, alpha_beta[0], alpha_beta[1]
                )
            # Bonus row: same element-wise approximation, fused by XLA.
            inv = 1.0 / (alpha_beta[1] - alpha_beta[0])
            bonus = jax.nn.sigmoid((z_p[:, -1, :] - alpha_beta[0]) * inv)
            return _finish(tau, a, b, bonus, draft, u_acc, u_res, u_bonus)

        return fn

    # "sigmoid16": the paper's actual numeric regime — Whisper logits are
    # fp16, and the (z - α)/(β - α) rescaling is performed in half
    # precision. At |α| = |β| = 1e5 the subtraction overflows fp16
    # (max 65504) to inf, the division yields inf/inf = NaN, every
    # acceptance test fails and resampling draws from a NaN residual —
    # reproducing Table 2's WER-29.34 / −10826% catastrophic row, which
    # pure-f32 arithmetic cannot show.
    def fn(z_p, z_q, draft, u_acc, u_res, u_bonus, alpha_beta):
        def approx(z):
            ab16 = alpha_beta.astype(jnp.float16)
            z16 = z.astype(jnp.float16)
            scaled = (z16 - ab16[0]) / (ab16[1] - ab16[0])  # fp16 math
            return jax.nn.sigmoid(scaled.astype(jnp.float32))

        p = approx(z_p)
        q = approx(z_q)
        # unguarded ratio, as the torch implementation computes it: when the
        # fp16 rescale produced NaN the ratio stays NaN, u <= NaN is false,
        # and every draft is rejected — the paper's observed failure mode.
        tau = jnp.minimum(1.0, p[:, :-1, :] / q)
        a = jnp.maximum(p[:, :-1, :] - q, 0.0)
        b = jnp.sum(a, axis=-1)
        return _finish(tau, a, b, p[:, -1, :], draft, u_acc, u_res, u_bonus)

    return fn


def make_sample_fn() -> Callable:
    """Categorical draw from logits with temperature, inverse-CDF style.

    fn(logits (B,V), u (B,), temp (B,)) -> token i32 (B,)
    temp <= 0 selects greedy argmax (used by the engine's greedy mode and
    by the draft model when a request asks for deterministic drafting).
    """

    def fn(logits, u, temp):
        safe_t = jnp.where(temp > 0.0, temp, 1.0)
        p = ref.softmax(logits / safe_t[:, None])
        sampled = ref.inverse_cdf_sample(p, u)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(temp > 0.0, sampled, greedy)

    return fn
