"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + manifest.json.

This is the only place python touches the system. ``make artifacts`` runs
it once; the rust coordinator (L3) then loads every executable it needs
from ``artifacts/`` via PJRT and never calls back into python.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact family (DESIGN.md §4):
  draft_step_<pair>_b<B>    (tokens, lens, u, temp) -> (next_tok, logits)
  target_step_<pair>_b<B>   same, target model (plain autoregressive mode)
  target_score_<pair>_b<B>  (tokens, lens) -> logits at last GMAX+1 positions
  verify_<method>_b<B>_g<G>_v<V>  fused verification (see verify_graph.py)

Verify graphs are model-independent (they consume logits), so the engine
set (V = model vocab) is complemented by kernel-bench sets at the paper's
vocabulary scale (V = 4096 / 32768) without retraining anything.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as m
from compile import train
from compile.verify_graph import make_sample_fn, make_verify_fn

GMAX = 20  # target_score always returns GMAX+1 positions; rust slices
ENGINE_BATCHES = (1, 4)
ENGINE_GAMMAS = tuple(range(1, GMAX + 1))
BENCH_SPECS = (  # (V, B, gammas) at paper-scale vocabularies
    (4096, 1, (1, 2, 3, 5, 8, 10, 15, 20)),
    (32768, 1, (1, 2, 3, 5, 8, 10, 15, 20)),
)
PAIRS = {"base": ("target-base", "draft-base"),
         "large": ("target-large", "draft-large")}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: model weights are baked into the graph; the
    # default elides them as `constant({...})`, which the rust-side HLO
    # parser would reject.
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _iospec(avals) -> List[List]:
    return [[str(a.dtype), list(a.shape)] for a in avals]


class Builder:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.entries: List[dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def lower(self, name: str, fn: Callable, in_specs: Sequence, meta: dict) -> None:
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        if self.force or not os.path.exists(path):
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            status = f"{len(text)/1e6:.2f}MB in {time.time()-t0:.2f}s"
        else:
            status = "cached"
        entry = dict(meta)
        entry.update(
            name=name,
            file=f"{name}.hlo.txt",
            inputs=_iospec(in_specs),
            outputs=_iospec(list(out_avals)),
        )
        self.entries.append(entry)
        print(f"[aot] {name}: {status}")


def build_model_artifacts(b: Builder, tok: train.CharTokenizer,
                          param_paths: Dict[str, str], batches: Sequence[int],
                          pairs: Dict[str, Tuple[str, str]]) -> None:
    sample = make_sample_fn()
    for pair, (tname, dname) in pairs.items():
        tcfg, dcfg = m.PRESETS[tname], m.PRESETS[dname]
        tparams = train.load_params(param_paths[tname], tcfg)
        dparams = train.load_params(param_paths[dname], dcfg)
        s, v = tcfg.max_seq, tcfg.vocab_size

        for bsz in batches:
            tok_spec = spec((bsz, s), jnp.int32)
            len_spec = spec((bsz,), jnp.int32)
            u_spec = spec((bsz,), jnp.float32)

            def step_fn(params, cfg):
                def fn(tokens, lens, u, temp):
                    logits = m.next_logits(params, cfg, tokens, lens)
                    return sample(logits, u, temp), logits
                return fn

            b.lower(
                f"draft_step_{pair}_b{bsz}",
                step_fn(dparams, dcfg),
                (tok_spec, len_spec, u_spec, u_spec),
                dict(kind="draft_step", pair=pair, b=bsz, s=s, v=v),
            )
            b.lower(
                f"target_step_{pair}_b{bsz}",
                step_fn(tparams, tcfg),
                (tok_spec, len_spec, u_spec, u_spec),
                dict(kind="target_step", pair=pair, b=bsz, s=s, v=v),
            )

            def score_fn(tokens, lens):
                return (m.logits_at(tparams, tcfg, tokens, lens, GMAX + 1),)

            b.lower(
                f"target_score_{pair}_b{bsz}",
                score_fn,
                (tok_spec, len_spec),
                dict(kind="target_score", pair=pair, b=bsz, s=s, v=v, gmax=GMAX),
            )

            # self-speculative drafting (§A.7): draft by running only the
            # first half of the *target* model's layers — no separate draft
            # network, same verification afterwards.
            half = max(1, tcfg.n_layers // 2)

            def self_step_fn(tokens, lens, u, temp, _half=half):
                full = m.forward(tparams, tcfg, tokens, lens, num_layers=_half)
                idx = jnp.maximum(lens - 1, 0)
                logits = jnp.take_along_axis(full, idx[:, None, None], axis=1)[:, 0, :]
                return sample(logits, u, temp), logits

            b.lower(
                f"draft_self_step_{pair}_b{bsz}",
                self_step_fn,
                (tok_spec, len_spec, u_spec, u_spec),
                dict(kind="draft_self_step", pair=pair, b=bsz, s=s, v=v,
                     skip_to_layers=half),
            )


def build_verify_artifacts(b: Builder, v: int, bsz: int,
                           gammas: Sequence[int], tile: int = 1024,
                           methods: Sequence[str] = ("baseline", "exact",
                                                     "sigmoid", "sigmoid16"),
                           name_suffix: str = "") -> None:
    for g in gammas:
        zp = spec((bsz, g + 1, v), jnp.float32)
        zq = spec((bsz, g, v), jnp.float32)
        dr = spec((bsz, g), jnp.int32)
        ua = spec((bsz, g), jnp.float32)
        ub = spec((bsz,), jnp.float32)
        ab = spec((2,), jnp.float32)
        for method in methods:
            takes_ab = method.startswith("sigmoid")
            fn = make_verify_fn(method, tile=tile, interpret=True)
            ins = (zp, zq, dr, ua, ub, ub) + ((ab,) if takes_ab else ())
            b.lower(
                f"verify_{method}_b{bsz}_g{g}_v{v}{name_suffix}",
                fn,
                ins,
                dict(kind="verify", method=method, b=bsz, g=g, v=v,
                     tile=min(tile, v),
                     alpha_beta_runtime=takes_ab),
            )


def build_all(out_dir: str, corpus: str, quick: bool = False,
              force: bool = False, train_steps: int = 400) -> dict:
    t0 = time.time()
    pairs = PAIRS if not quick else {"base": PAIRS["base"]}
    pairs_to_train = tuple(
        (name, train_steps if not quick else 40)
        for pair in pairs.values()
        for name in pair
    )
    tok, param_paths, curves = train.ensure_trained(
        out_dir, corpus, pairs=pairs_to_train, force=force)

    b = Builder(out_dir, force=force)
    batches = (1,) if quick else ENGINE_BATCHES
    build_model_artifacts(b, tok, param_paths, batches, pairs)
    vmodel = tok.vocab_size
    gammas = (1, 2, 5) if quick else ENGINE_GAMMAS
    for bsz in batches:
        build_verify_artifacts(b, vmodel, bsz, gammas)
    bench = ((4096, 1, (1, 5)),) if quick else BENCH_SPECS
    for v, bsz, gs in bench:
        build_verify_artifacts(b, v, bsz, gs)
    # tile-size ablation (DESIGN §5): the paper fixes n = 1024 (max
    # threads/block); these variants let the kernel bench compare tilings.
    if not quick:
        for t in (128, 256, 512):
            build_verify_artifacts(b, 32768, 1, (5,), tile=t,
                                   methods=("exact",), name_suffix=f"_t{t}")

    manifest = {
        "version": 1,
        "vocab_size": tok.vocab_size,
        "seq_len": m.PRESETS["target-base"].max_seq,
        "gmax": GMAX,
        "pairs": {
            pair: {
                "target": tname,
                "draft": dname,
                "target_params": m.PRESETS[tname].param_count(),
                "draft_params": m.PRESETS[dname].param_count(),
            }
            for pair, (tname, dname) in pairs.items()
        },
        "loss_curves": curves,
        "artifacts": b.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(b.entries)} artifacts + manifest "
          f"in {time.time()-t0:.1f}s")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--corpus", default="../data/corpus.txt")
    ap.add_argument("--quick", action="store_true",
                    help="reduced artifact set for CI/tests")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--train-steps", type=int, default=400)
    args = ap.parse_args()
    if not os.path.exists(args.corpus):
        from compile import gen_corpus
        os.makedirs(os.path.dirname(args.corpus) or ".", exist_ok=True)
        with open(args.corpus, "w") as f:
            f.write(gen_corpus.generate(300_000))
        print(f"[aot] generated corpus at {args.corpus}")
    build_all(args.out, args.corpus, quick=args.quick, force=args.force,
              train_steps=args.train_steps)


if __name__ == "__main__":
    main()
