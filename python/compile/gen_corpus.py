"""Deterministic synthetic corpus generator.

The paper evaluates on LibriSpeech/TED-LIUM/CommonVoice (ASR) and
Xsum/CNN-DM (summarization). Those corpora (and the Whisper/Llama2
checkpoints trained on them) are not available in this environment, so we
substitute a deterministic, grammar-generated English-like corpus that the
build-time draft/target LMs can actually learn. What speculative sampling
cares about is the *agreement structure* between draft and target
distributions — both models fitting the same low-entropy corpus reproduces
the paper's 45-60% token acceptance regime (Table 8).

The generator is a small probabilistic grammar with a fixed word inventory,
seeded PCG-style so `make artifacts` is reproducible bit-for-bit.
"""

from __future__ import annotations

import argparse
import os

SUBJECTS = [
    "the scheduler", "a worker thread", "the target model", "the draft model",
    "the request router", "a decoding step", "the verification kernel",
    "the memory pool", "the batch planner", "a streaming client",
    "the profiler", "the token buffer", "the sampling loop", "an accelerator",
    "the runtime", "a cache line", "the reduction tree", "the event loop",
]

VERBS = [
    "accepts", "rejects", "verifies", "samples", "schedules", "batches",
    "loads", "stores", "computes", "reduces", "streams", "emits",
    "profiles", "measures", "drafts", "resamples", "tracks", "updates",
]

OBJECTS = [
    "the drafted tokens", "a probability tile", "the partial sums",
    "the acceptance ratio", "the residual distribution", "a vocabulary slice",
    "the logits", "the next request", "a batch of sequences",
    "the uniform draws", "the bonus token", "the prefix", "the kv state",
    "an output literal", "the decode queue", "the latency histogram",
]

ADVERBS = [
    "in parallel", "within one block", "without synchronization",
    "per decoding step", "under backpressure", "at full occupancy",
    "before the barrier", "after the reduction", "on the hot path",
    "with bounded memory", "once per step", "deterministically",
]

CONNECTIVES = ["and then", "so that", "while", "because", "after which"]


class Pcg32:
    """Minimal PCG32 (matches rust/src/util/rng.rs stream semantics)."""

    MULT = 6364136223846793005
    MASK = (1 << 64) - 1

    def __init__(self, seed: int, stream: int = 54):
        self.inc = ((stream << 1) | 1) & self.MASK
        self.state = 0
        self.next_u32()
        self.state = (self.state + (seed & self.MASK)) & self.MASK
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * self.MULT + self.inc) & self.MASK
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def below(self, n: int) -> int:
        return self.next_u32() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]


def sentence(rng: Pcg32) -> str:
    parts = [rng.choice(SUBJECTS), rng.choice(VERBS), rng.choice(OBJECTS)]
    if rng.below(100) < 70:
        parts.append(rng.choice(ADVERBS))
    s = " ".join(parts)
    if rng.below(100) < 30:
        s += " " + rng.choice(CONNECTIVES) + " " + " ".join(
            [rng.choice(SUBJECTS), rng.choice(VERBS), rng.choice(OBJECTS)]
        )
    return s[0].upper() + s[1:] + "."


def paragraph(rng: Pcg32) -> str:
    n = 3 + rng.below(5)
    return " ".join(sentence(rng) for _ in range(n))


def generate(size_bytes: int, seed: int = 7) -> str:
    rng = Pcg32(seed)
    chunks = []
    total = 0
    while total < size_bytes:
        p = paragraph(rng)
        chunks.append(p)
        total += len(p) + 2
    return "\n\n".join(chunks) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="data/corpus.txt")
    ap.add_argument("--size", type=int, default=300_000)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    text = generate(args.size, args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"corpus: wrote {len(text)} bytes to {args.out}")


if __name__ == "__main__":
    main()
