"""Build-time training of the draft/target LM pairs.

Runs once inside ``make artifacts`` (never on the request path): trains the
preset model pairs from ``model.PRESETS`` on ``data/corpus.txt`` with a
hand-rolled Adam (optax is not available in the build image) and caches the
resulting parameter pytrees as .npz files keyed by a config+corpus hash.

The point is not SOTA modelling — it is that draft and target fit the same
distribution so the serving engine operates in the paper's 45-60%
acceptance regime (Table 8). A few hundred steps on the synthetic corpus
reach per-char perplexity < 3, which is plenty.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as m


# ---------------------------------------------------------------------------
# tokenizer


class CharTokenizer:
    """Char-level tokenizer with pad/bos/eos specials.

    The vocab is padded to `pad_to` entries so the verify kernels see a
    multi-of-128 vocabulary (and the rust tokenizer loads the same table
    from artifacts/tokenizer.json).
    """

    PAD, BOS, EOS = 0, 1, 2

    def __init__(self, chars: List[str], pad_to: int = 128):
        self.chars = chars
        self.stoi = {c: i + 3 for i, c in enumerate(chars)}
        self.vocab_size = max(pad_to, len(chars) + 3)

    @classmethod
    def from_text(cls, text: str, pad_to: int = 128) -> "CharTokenizer":
        return cls(sorted(set(text)), pad_to=pad_to)

    def encode(self, s: str) -> List[int]:
        # unknown chars map to pad (never produced by the generator)
        return [self.stoi.get(c, self.PAD) for c in s]

    def decode(self, ids) -> str:
        inv = {v: k for k, v in self.stoi.items()}
        return "".join(inv.get(int(i), "") for i in ids)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {
                    "type": "char",
                    "vocab_size": self.vocab_size,
                    "specials": {"pad": self.PAD, "bos": self.BOS, "eos": self.EOS},
                    "chars": self.chars,
                },
                f,
            )


# ---------------------------------------------------------------------------
# data


def batches(text_ids: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.RandomState(seed)
    n = len(text_ids) - seq - 1
    for _ in range(steps):
        starts = rng.randint(0, n, size=batch)
        yield np.stack([text_ids[s : s + seq] for s in starts]).astype(np.int32)


# ---------------------------------------------------------------------------
# hand-rolled Adam


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, mi, vi: p - lr * (mi * mhat_scale) / (jnp.sqrt(vi * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# param (de)serialisation — flat npz with path-encoded keys


def flatten_params(params, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(flatten_params(v, f"{prefix}{k}/"))
    elif isinstance(params, list):
        for i, v in enumerate(params):
            out.update(flatten_params(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def save_params(path: str, params) -> None:
    np.savez(path, **flatten_params(params))


def load_params(path: str, cfg: m.ModelConfig):
    """Rebuild the pytree in the shape init_params produces."""
    flat = dict(np.load(path))
    params = {
        "tok_emb": jnp.asarray(flat["tok_emb"]),
        "pos_emb": jnp.asarray(flat["pos_emb"]),
        "final_norm": jnp.asarray(flat["final_norm"]),
        "lm_head": jnp.asarray(flat["lm_head"]),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        layer = {}
        for name in (
            "attn_norm wq wk wv wo mlp_norm w_gate w_up w_down".split()
        ):
            layer[name] = jnp.asarray(flat[f"layers/{i}/{name}"])
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# training loop


def corpus_hash(text: str, cfg: m.ModelConfig, steps: int, seed: int) -> str:
    h = hashlib.sha256()
    h.update(text.encode())
    h.update(repr((cfg, steps, seed)).encode())
    return h.hexdigest()[:16]


def train_model(
    name: str,
    cfg: m.ModelConfig,
    text_ids: np.ndarray,
    steps: int,
    seed: int,
    batch: int = 32,
    seq: int = 128,
    lr: float = 2e-3,
    log_every: int = 50,
) -> Tuple[dict, List[float]]:
    params = m.init_params(cfg, seed)
    state = adam_init(params)
    lens = jnp.full((batch,), seq, jnp.int32)

    @jax.jit
    def step(params, state, toks):
        loss, grads = jax.value_and_grad(m.loss_fn)(params, cfg, toks, lens)
        params, state = adam_update(params, grads, state, lr=lr)
        return params, state, loss

    losses = []
    t0 = time.time()
    for i, toks in enumerate(batches(text_ids, batch, seq, steps, seed + 1)):
        params, state, loss = step(params, state, jnp.asarray(toks))
        if i % log_every == 0 or i == steps - 1:
            l = float(loss)
            losses.append(l)
            print(f"[train {name}] step {i:4d}/{steps} loss {l:.4f} "
                  f"({time.time()-t0:.1f}s)")
    return params, losses


def ensure_trained(
    out_dir: str,
    corpus_path: str,
    pairs=(("target-base", 400), ("draft-base", 400),
           ("target-large", 400), ("draft-large", 400)),
    seed: int = 11,
    force: bool = False,
) -> Tuple[CharTokenizer, Dict[str, str], Dict[str, List[float]]]:
    """Train (or load cached) all preset models. Returns tokenizer + paths."""
    os.makedirs(out_dir, exist_ok=True)
    with open(corpus_path) as f:
        text = f.read()
    tok = CharTokenizer.from_text(text)
    tok.save(os.path.join(out_dir, "tokenizer.json"))
    ids = np.asarray(tok.encode(text), dtype=np.int32)

    paths, curves = {}, {}
    for name, steps in pairs:
        cfg = m.PRESETS[name]
        assert cfg.vocab_size == tok.vocab_size, (
            f"{name}: preset vocab {cfg.vocab_size} != tokenizer {tok.vocab_size}"
        )
        tag = corpus_hash(text, cfg, steps, seed)
        path = os.path.join(out_dir, f"params_{name}.npz")
        meta = os.path.join(out_dir, f"params_{name}.json")
        if not force and os.path.exists(path) and os.path.exists(meta):
            with open(meta) as f:
                if json.load(f).get("hash") == tag:
                    print(f"[train] cache hit for {name}")
                    paths[name] = path
                    continue
        params, losses = train_model(name, cfg, ids, steps, seed)
        save_params(path, params)
        with open(meta, "w") as f:
            json.dump({"hash": tag, "loss_curve": losses,
                       "param_count": cfg.param_count()}, f)
        paths[name] = path
        curves[name] = losses
    return tok, paths, curves


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="../data/corpus.txt")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    pairs = tuple((n, args.steps) for n in
                  ("target-base", "draft-base", "target-large", "draft-large"))
    ensure_trained(args.out, args.corpus, pairs=pairs, force=args.force)


if __name__ == "__main__":
    main()
