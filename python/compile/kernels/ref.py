"""Pure-jnp oracle for the Pallas verification kernels.

Untiled, straight-line implementations of the quantities in §3.1 Eqs. 1-3.
Every Pallas kernel output is asserted against these in
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes); the
rust-side oracle (``rust/src/sampling``) mirrors the same math so the three
implementations triangulate each other.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def softmax(z: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over the last axis (Eq. 4)."""
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def sigmoid_approx(z: jnp.ndarray, alpha: float, beta: float) -> jnp.ndarray:
    """Element-wise softmax approximation (Eq. 5)."""
    return jax.nn.sigmoid((z - alpha) / (beta - alpha))


def ref_verify(p: jnp.ndarray, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for ``verify_tiles_exact``: (tau, a, b) from probabilities."""
    safe_q = jnp.where(q > 0.0, q, 1.0)
    tau = jnp.where(q > 0.0, jnp.minimum(1.0, p / safe_q), 1.0)
    a = jnp.maximum(p - q, 0.0)
    b = jnp.sum(a, axis=-1)
    return tau, a, b


def ref_verify_sigmoid(
    z_p: jnp.ndarray, z_q: jnp.ndarray, alpha: float, beta: float
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for ``verify_tiles_sigmoid``: same math on approximated probs."""
    return ref_verify(sigmoid_approx(z_p, alpha, beta), sigmoid_approx(z_q, alpha, beta))


def inverse_cdf_sample(weights: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Draw from an *unnormalised* weight vector by inverse CDF.

    weights: (..., V) non-negative; u: (...) uniforms in [0, 1).
    Returns i32 (...) token ids. Avoids the paper's step-3 division
    entirely: the threshold is u * sum(weights) on the raw cumulative sum.
    Zero-mass rows fall back to argmax(weights) (== 0 for all-zero rows).
    """
    cdf = jnp.cumsum(weights, axis=-1)
    total = cdf[..., -1]
    thresh = u * total
    tok = jnp.sum((cdf <= thresh[..., None]).astype(jnp.int32), axis=-1)
    tok = jnp.minimum(tok, weights.shape[-1] - 1)
    return jnp.where(total > 0.0, tok, jnp.argmax(weights, axis=-1).astype(jnp.int32))
