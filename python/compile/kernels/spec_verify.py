"""L1: Pallas kernels for fused speculative-sampling verification.

Implements the paper's two kernels (§3.2):

* ``verify_tiles_exact``  — Fig. 1: inputs are the *probability* matrices
  p, q of shape (B, G, V). The vocabulary axis is partitioned into K =
  ceil(V/n) tiles; each grid step (b, g, k) stages one (1, 1, n) tile of p
  and q into VMEM (the TPU analogue of the paper's SRAM staging), computes
  the element-wise intermediates

      tau(x) = min(1, p(x)/q(x))        (Eq. 1, over the whole tile)
      f(x)   = p(x) - q(x)              (Eq. 2)
      a(x)   = max(0, f(x))             (Eq. 3 numerator)

  and the per-tile partial reduction b_k = sum_x a(x) (Eq. 3 denominator),
  writing tau, a back to HBM and b_k to a (B, G, K) partial-sum output.
  The cross-tile aggregation of b and the final division/resampling happen
  outside the kernel, exactly as in the paper's step 3.

* ``verify_tiles_sigmoid`` — Fig. 2: inputs are the raw *logits* z_p, z_q;
  the kernel additionally applies the element-wise softmax approximation

      p_hat(x) = sigmoid((z_p(x) - alpha) / (beta - alpha))     (Eq. 5)

  fused with the same tau/f/a/b_k computation, removing softmax's global
  max/sum reductions from the pipeline. alpha/beta arrive as a (2,)
  runtime parameter vector so one compiled artifact serves the whole
  Table 2 scaling sweep.

Hardware adaptation (DESIGN.md §2): the CUDA thread-block over a 1024-wide
vocabulary slice becomes a Pallas ``BlockSpec`` block of n=1024 on the
vocab axis; HBM→SRAM staging becomes the implicit HBM→VMEM copy of the
block; the intra-block parallel reduction (Harris 2007) becomes a vector
``jnp.sum`` over the VMEM-resident tile. Kernels are lowered with
``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls — so the
tiling is validated structurally + numerically here and costed for real
hardware by ``rust/src/simulator``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 1024  # = paper's n: max threads/block on A100


def _pad_vocab(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    v = x.shape[-1]
    k = -(-v // tile)
    pad = k * tile - v
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def _exact_kernel(p_ref, q_ref, tau_ref, a_ref, bk_ref):
    """One (b, g, k) grid step over a (1, 1, n) vocab tile (steps 1-3, Fig 1)."""
    p = p_ref[...]
    q = q_ref[...]
    # tau = min(1, p/q); tokens with q == 0 can never be drafted, so their
    # ratio is defined as 1 (always-accept) to keep the tile NaN-free.
    safe_q = jnp.where(q > 0.0, q, 1.0)
    tau = jnp.where(q > 0.0, jnp.minimum(1.0, p / safe_q), 1.0)
    f = p - q
    a = jnp.maximum(f, 0.0)
    tau_ref[...] = tau
    a_ref[...] = a
    # per-tile partial reduction (paper's b_k, computed in SRAM/VMEM)
    bk_ref[...] = jnp.sum(a, axis=-1, keepdims=True)


def _sigmoid_kernel(params_ref, zp_ref, zq_ref, tau_ref, a_ref, bk_ref):
    """Fig. 2 variant: fuse the sigmoid softmax-approximation into the tile."""
    alpha = params_ref[0]
    beta = params_ref[1]
    inv = 1.0 / (beta - alpha)
    p = jax.nn.sigmoid((zp_ref[...] - alpha) * inv)
    q = jax.nn.sigmoid((zq_ref[...] - alpha) * inv)
    # sigmoid output is (0, 1) but can underflow to 0 in f32 — same guard.
    safe_q = jnp.where(q > 0.0, q, 1.0)
    tau = jnp.where(q > 0.0, jnp.minimum(1.0, p / safe_q), 1.0)
    f = p - q
    a = jnp.maximum(f, 0.0)
    tau_ref[...] = tau
    a_ref[...] = a
    bk_ref[...] = jnp.sum(a, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def verify_tiles_exact(
    p: jnp.ndarray,
    q: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused exact verification tiles.

    p, q: f32 (B, G, V) probability matrices.
    Returns (tau (B,G,V), a (B,G,V), b (B,G)) with b already aggregated
    across tiles (the paper's step-3 HBM aggregation — a K-length sum).
    """
    assert p.shape == q.shape and p.ndim == 3
    b_, g_, v = p.shape
    n = min(tile, v)
    pp, qp = _pad_vocab(p, n), _pad_vocab(q, n)
    k = pp.shape[-1] // n
    # Perf iteration 1 (EXPERIMENTS.md §Perf): one grid step per (batch,
    # vocab-tile) processing ALL γ rows — a (1, γ, n) VMEM block instead of
    # (1, 1, n). On TPU this is the natural (sublane, lane) = (γ, n) tile;
    # under interpret-mode CPU lowering it cuts the per-grid-step
    # dynamic-update-slice traffic by γ× (measured 60ms → 12ms at γ=5,
    # V=32768). γ ≤ 20 keeps the block ≤ 21·1024·4B ≈ 86KiB of VMEM.
    grid = (b_, k)
    vec_spec = pl.BlockSpec((1, g_, n), lambda i, t: (i, 0, t))
    bk_spec = pl.BlockSpec((1, g_, 1), lambda i, t: (i, 0, t))
    tau, a, bk = pl.pallas_call(
        _exact_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec, bk_spec],
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, pp.dtype),
            jax.ShapeDtypeStruct(pp.shape, pp.dtype),
            jax.ShapeDtypeStruct((b_, g_, k), pp.dtype),
        ],
        interpret=interpret,
    )(pp, qp)
    return tau[..., :v], a[..., :v], jnp.sum(bk, axis=-1)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def verify_tiles_sigmoid(
    z_p: jnp.ndarray,
    z_q: jnp.ndarray,
    alpha_beta: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused sigmoid-approximated verification tiles.

    z_p, z_q: f32 (B, G, V) *logit* matrices; alpha_beta: f32 (2,) = (α, β).
    Returns (tau_hat, a_hat, b_hat) analogous to ``verify_tiles_exact``.
    Padding lanes are benign: sigmoid(pad 0) is equal for p/q, so a = 0 and
    the padded lanes contribute nothing to b; they are sliced off anyway.
    """
    assert z_p.shape == z_q.shape and z_p.ndim == 3
    b_, g_, v = z_p.shape
    n = min(tile, v)
    zpp, zqp = _pad_vocab(z_p, n), _pad_vocab(z_q, n)
    k = zpp.shape[-1] // n
    # same (1, γ, n) blocking as the exact kernel (perf iteration 1)
    grid = (b_, k)
    par_spec = pl.BlockSpec((2,), lambda i, t: (0,))
    vec_spec = pl.BlockSpec((1, g_, n), lambda i, t: (i, 0, t))
    bk_spec = pl.BlockSpec((1, g_, 1), lambda i, t: (i, 0, t))
    tau, a, bk = pl.pallas_call(
        _sigmoid_kernel,
        grid=grid,
        in_specs=[par_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec, bk_spec],
        out_shape=[
            jax.ShapeDtypeStruct(zpp.shape, zpp.dtype),
            jax.ShapeDtypeStruct(zpp.shape, zpp.dtype),
            jax.ShapeDtypeStruct((b_, g_, k), zpp.dtype),
        ],
        interpret=interpret,
    )(alpha_beta.astype(z_p.dtype), zpp, zqp)
    return tau[..., :v], a[..., :v], jnp.sum(bk, axis=-1)


def vmem_bytes(gamma: int, tile: int = DEFAULT_TILE, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (perf model, DESIGN §7).

    Since perf iteration 1 a grid step holds (1, γ, n) tiles: two inputs,
    two outputs, plus the (γ, 1) partial sums. Grows linearly in γ but at
    γ=20, n=1024, f32 stays ≈ 82KiB×4 ≈ well inside one SM/SMEM budget of
    192KiB when counted against the paper's fp16 tiles (γ·n·2B·4 ≈ 164KiB)
    — the same occupancy argument as the paper's n = 1024 choice.
    """
    return (2 + 2) * gamma * tile * dtype_bytes + gamma * dtype_bytes
