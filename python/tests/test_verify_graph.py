"""L2 semantics: the fused verification graphs.

The acceptance/resample/bonus tail is checked against an *independent*
step-by-step numpy oracle (written procedurally, not by reusing the jnp
graph), and `exact` is asserted bit-identical to `baseline`.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.verify_graph import make_sample_fn, make_verify_fn


# ---------------------------------------------------------------------------
# independent numpy oracle


def np_softmax(z):
    m = z.max(axis=-1, keepdims=True)
    e = np.exp(z - m)
    return e / e.sum(axis=-1, keepdims=True)


def np_sigmoid(z, alpha, beta):
    return 1.0 / (1.0 + np.exp(-((z - alpha) / (beta - alpha))))


def np_inverse_cdf(w, u):
    total = w.sum()
    if total <= 0.0:
        return int(np.argmax(w))
    cdf = np.cumsum(w)
    tok = int(np.sum(cdf <= u * total))
    return min(tok, len(w) - 1)


def oracle_step(z_p, z_q, draft, u_acc, u_res, u_bonus, method, alpha=None, beta=None):
    """Speculative verification for ONE batch row, straight from Eq. 1-3."""
    g = draft.shape[0]
    if method == "sigmoid":
        p = np_sigmoid(z_p.astype(np.float32), alpha, beta)
        q = np_sigmoid(z_q.astype(np.float32), alpha, beta)
    else:
        p = np_softmax(z_p.astype(np.float32))
        q = np_softmax(z_q.astype(np.float32))
    accept_len = g
    for c in range(g):
        x = int(draft[c])
        qc = q[c, x]
        tau = 1.0 if qc <= 0.0 else min(1.0, p[c, x] / qc)
        if u_acc[c] > tau:
            accept_len = c
            break
    out = np.full(g + 1, -1, np.int64)
    out[:accept_len] = draft[:accept_len]
    if accept_len == g:
        out[g] = np_inverse_cdf(p[g], u_bonus)
    else:
        residual = np.maximum(p[accept_len] - q[accept_len], 0.0)
        out[accept_len] = np_inverse_cdf(residual, u_res)
    return accept_len, out


def make_inputs(seed, b, g, v, scale=3.0):
    rng = np.random.RandomState(seed)
    z_p = rng.randn(b, g + 1, v).astype(np.float32) * scale
    z_q = rng.randn(b, g, v).astype(np.float32) * scale
    draft = rng.randint(0, v, size=(b, g)).astype(np.int32)
    u_acc = rng.rand(b, g).astype(np.float32)
    u_res = rng.rand(b).astype(np.float32)
    u_bonus = rng.rand(b).astype(np.float32)
    return z_p, z_q, draft, u_acc, u_res, u_bonus


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 8), st.integers(4, 300))
def test_baseline_matches_numpy_oracle(seed, b, g, v):
    ins = make_inputs(seed, b, g, v)
    fn = make_verify_fn("baseline")
    alen, out, _tau = fn(*map(jnp.asarray, ins))
    for row in range(b):
        exp_len, exp_out = oracle_step(
            ins[0][row], ins[1][row], ins[2][row],
            ins[3][row], ins[4][row], ins[5][row], "baseline")
        assert int(alen[row]) == exp_len, f"row {row}"
        np.testing.assert_array_equal(np.asarray(out[row]), exp_out)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 2), st.integers(1, 8), st.integers(4, 300))
def test_exact_bit_identical_to_baseline(seed, b, g, v):
    ins = tuple(map(jnp.asarray, make_inputs(seed, b, g, v)))
    ob = make_verify_fn("baseline")(*ins)
    oe = make_verify_fn("exact")(*ins)
    np.testing.assert_array_equal(np.asarray(ob[0]), np.asarray(oe[0]))
    np.testing.assert_array_equal(np.asarray(ob[1]), np.asarray(oe[1]))
    np.testing.assert_allclose(np.asarray(ob[2]), np.asarray(oe[2]), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 10_000),
    st.integers(1, 2),
    st.integers(1, 6),
    st.integers(4, 200),
    st.sampled_from([(-10.0, 10.0), (-1e3, 1e3), (-1e4, 1e4)]),
)
def test_sigmoid_matches_numpy_oracle(seed, b, g, v, ab):
    alpha, beta = ab
    ins = make_inputs(seed, b, g, v, scale=8.0)
    fn = make_verify_fn("sigmoid")
    alen, out, _ = fn(*map(jnp.asarray, ins), jnp.asarray([alpha, beta], jnp.float32))
    for row in range(b):
        exp_len, exp_out = oracle_step(
            ins[0][row], ins[1][row], ins[2][row],
            ins[3][row], ins[4][row], ins[5][row], "sigmoid", alpha, beta)
        assert int(alen[row]) == exp_len
        np.testing.assert_array_equal(np.asarray(out[row]), exp_out)


def test_all_accept_emits_bonus():
    # p == q => tau == 1 everywhere => everything accepted, bonus emitted.
    b, g, v = 2, 4, 32
    rng = np.random.RandomState(0)
    z = rng.randn(b, g + 1, v).astype(np.float32)
    draft = rng.randint(0, v, (b, g)).astype(np.int32)
    u = rng.rand(b, g).astype(np.float32)
    fn = make_verify_fn("baseline")
    alen, out, tau = fn(jnp.asarray(z), jnp.asarray(z[:, :g]), jnp.asarray(draft),
                        jnp.asarray(u), jnp.zeros(b), jnp.asarray([0.3, 0.9]))
    assert np.all(np.asarray(alen) == g)
    assert np.all(np.asarray(tau) == 1.0)
    assert np.all(np.asarray(out)[:, :g] == draft)
    assert np.all(np.asarray(out)[:, g] >= 0)


def test_immediate_reject_resamples_from_residual():
    # q concentrates on token 0, p on token 1; draft token 0 is rejected
    # whenever u_acc > p(0)/q(0), and the residual argmax is token 1.
    v = 8
    z_q = np.full((1, 1, v), -10.0, np.float32); z_q[0, 0, 0] = 10.0
    z_p = np.full((1, 2, v), -10.0, np.float32); z_p[0, :, 1] = 10.0
    fn = make_verify_fn("baseline")
    alen, out, tau = fn(jnp.asarray(z_p), jnp.asarray(z_q),
                        jnp.asarray([[0]], jnp.int32),
                        jnp.asarray([[0.9]], jnp.float32),
                        jnp.asarray([0.5], jnp.float32),
                        jnp.asarray([0.5], jnp.float32))
    assert int(alen[0]) == 0
    assert int(out[0, 0]) == 1  # residual mass sits on token 1
    assert np.all(np.asarray(out)[0, 1:] == -1)


def test_resample_distribution_matches_max_norm():
    """Chi-square: resampled tokens follow max_norm(p - q) (Eq. 2/3)."""
    v = 16
    rng = np.random.RandomState(42)
    z_p = rng.randn(1, 2, v).astype(np.float32) * 2
    z_q = rng.randn(1, 1, v).astype(np.float32) * 2
    p = np_softmax(z_p[0, 0]); q = np_softmax(z_q[0, 0])
    residual = np.maximum(p - q, 0.0)
    residual /= residual.sum()

    fn = make_verify_fn("baseline")
    # draft the token q loves but p hates -> frequent rejection
    draft_tok = int(np.argmax(q - p))
    n = 20_000
    counts = np.zeros(v)
    us = np.linspace(0.0, 1.0, n, endpoint=False) + 0.5 / n  # stratified
    alen, out, _ = jnp.broadcast_to, None, None
    z_p_j = jnp.asarray(np.repeat(z_p, 1, 0))
    for chunk in np.array_split(us, 20):
        b = len(chunk)
        alen_c, out_c, _ = fn(
            jnp.asarray(np.repeat(z_p, b, 0)), jnp.asarray(np.repeat(z_q, b, 0)),
            jnp.full((b, 1), draft_tok, jnp.int32),
            jnp.ones((b, 1), jnp.float32),  # u_acc = 1 -> reject unless tau == 1
            jnp.asarray(chunk.astype(np.float32)),
            jnp.zeros(b, jnp.float32))
        toks = np.asarray(out_c)[:, 0]
        assert np.all(np.asarray(alen_c) == 0)
        for t in toks:
            counts[t] += 1
    expected = residual * n
    mask = expected > 5
    chi2 = np.sum((counts[mask] - expected[mask]) ** 2 / expected[mask])
    dof = mask.sum() - 1
    # stratified sampling makes this extremely tight; 3*dof is generous
    assert chi2 < 3 * max(dof, 1), (chi2, dof, counts, expected)


def test_out_tokens_shape_and_padding_invariants():
    ins = tuple(map(jnp.asarray, make_inputs(7, 3, 5, 64)))
    alen, out, tau = make_verify_fn("exact")(*ins)
    out = np.asarray(out); alen = np.asarray(alen)
    assert out.shape == (3, 6)
    for r in range(3):
        k = alen[r]
        assert np.all(out[r, :k] >= 0)
        assert out[r, k] >= 0  # resample/bonus slot always emitted
        assert np.all(out[r, k + 1:] == -1)


# ---------------------------------------------------------------------------
# sample_fn (draft/target fused sampling head)


def test_sample_fn_greedy_when_temp_zero():
    fn = make_sample_fn()
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]], jnp.float32)
    tok = fn(logits, jnp.asarray([0.7, 0.2]), jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(tok), [1, 0])


def test_sample_fn_inverse_cdf_deterministic():
    fn = make_sample_fn()
    logits = jnp.log(jnp.asarray([[0.1, 0.2, 0.7]], jnp.float32))
    # CDF = [.1, .3, 1.0]; u=0.05 -> 0, u=0.15 -> 1, u=0.95 -> 2
    for u, want in [(0.05, 0), (0.15, 1), (0.95, 2)]:
        tok = fn(logits, jnp.asarray([u], jnp.float32), jnp.ones(1))
        assert int(tok[0]) == want, u


def test_inverse_cdf_sample_edge_cases():
    w = jnp.asarray([0.0, 0.0, 1.0, 0.0], jnp.float32)
    assert int(ref.inverse_cdf_sample(w, jnp.asarray(0.999))) == 2
    assert int(ref.inverse_cdf_sample(w, jnp.asarray(0.0))) == 2
    zero = jnp.zeros(4, jnp.float32)
    assert int(ref.inverse_cdf_sample(zero, jnp.asarray(0.5))) == 0
