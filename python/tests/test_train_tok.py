"""Tokenizer, param (de)serialisation, Adam, corpus generator."""

import json
import os

import jax.numpy as jnp
import numpy as np

from compile import gen_corpus
from compile import model as m
from compile import train


def test_tokenizer_round_trip(tmp_path):
    text = "the scheduler accepts the drafted tokens."
    tok = train.CharTokenizer.from_text(text)
    ids = tok.encode(text)
    assert tok.decode(ids) == text
    assert min(ids) >= 3  # specials reserved
    p = tmp_path / "tok.json"
    tok.save(str(p))
    data = json.loads(p.read_text())
    assert data["vocab_size"] == tok.vocab_size >= 128
    assert data["specials"] == {"pad": 0, "bos": 1, "eos": 2}
    assert data["chars"] == tok.chars


def test_tokenizer_vocab_padding():
    tok = train.CharTokenizer.from_text("ab", pad_to=128)
    assert tok.vocab_size == 128


def test_params_save_load_round_trip(tmp_path):
    cfg = m.ModelConfig(vocab_size=32, d_model=16, n_layers=2, n_heads=2,
                        d_ff=32, max_seq=8)
    params = m.init_params(cfg, seed=3)
    path = str(tmp_path / "p.npz")
    train.save_params(path, params)
    loaded = train.load_params(path, cfg)
    for a, b in zip(
        jnp.broadcast_shapes and _leaves(params), _leaves(loaded)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _leaves(t):
    import jax
    return jax.tree_util.tree_leaves(t)


def test_adam_minimises_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = train.adam_init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, state = train.adam_update(params, grads, state, lr=5e-2)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_corpus_generator_deterministic_and_sized():
    a = gen_corpus.generate(10_000, seed=7)
    b = gen_corpus.generate(10_000, seed=7)
    c = gen_corpus.generate(10_000, seed=8)
    assert a == b
    assert a != c
    assert len(a) >= 10_000
    # printable english-like text only
    assert set(a) <= set(
        "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ .,\n-"
    )


def test_corpus_pcg_reference_stream():
    """Pin PCG32 outputs so the rust util::rng implementation can match."""
    rng = gen_corpus.Pcg32(seed=42, stream=54)
    got = [rng.next_u32() for _ in range(4)]
    # self-consistency (regression pin, values frozen at first implementation)
    rng2 = gen_corpus.Pcg32(seed=42, stream=54)
    assert [rng2.next_u32() for _ in range(4)] == got
    assert len(set(got)) == 4


def test_batches_shapes_and_determinism():
    ids = np.arange(1000, dtype=np.int32)
    b1 = list(train.batches(ids, batch=4, seq=16, steps=3, seed=5))
    b2 = list(train.batches(ids, batch=4, seq=16, steps=3, seed=5))
    assert len(b1) == 3
    for x, y in zip(b1, b2):
        assert x.shape == (4, 16)
        np.testing.assert_array_equal(x, y)
