"""AOT pipeline: lowering produces loadable, self-contained HLO text."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as m
from compile.verify_graph import make_verify_fn


def test_hlo_text_has_entry_and_no_elided_constants():
    fn = make_verify_fn("exact")
    v, g = 64, 2
    ins = (aot.spec((1, g + 1, v), jnp.float32), aot.spec((1, g, v), jnp.float32),
           aot.spec((1, g), jnp.int32), aot.spec((1, g), jnp.float32),
           aot.spec((1,), jnp.float32), aot.spec((1,), jnp.float32))
    text = aot.to_hlo_text(jax.jit(fn).lower(*ins))
    assert "ENTRY" in text
    assert "constant({...})" not in text  # print_large_constants=True
    assert "custom-call" not in text      # interpret-mode pallas only


def test_model_artifact_includes_weights():
    cfg = m.ModelConfig(vocab_size=32, d_model=16, n_layers=1, n_heads=2,
                        d_ff=32, max_seq=16)
    params = m.init_params(cfg, seed=0)

    def fn(tokens, lens):
        return (m.next_logits(params, cfg, tokens, lens),)

    text = aot.to_hlo_text(jax.jit(fn).lower(
        aot.spec((1, 16), jnp.int32), aot.spec((1,), jnp.int32)))
    # weights are baked in: text must be large relative to the op count
    assert "constant({...})" not in text
    assert len(text) > 20_000
    # exactly the two runtime parameters in the ENTRY computation
    entry = text[text.index("ENTRY"):]
    entry_block = entry[:entry.index("\n}")]
    n_params = sum(1 for line in entry_block.splitlines()
                   if " parameter(" in line)
    assert n_params == 2, entry_block[:500]


def test_builder_writes_manifest_entry(tmp_path):
    b = aot.Builder(str(tmp_path))
    fn = make_verify_fn("baseline")
    v, g = 16, 1
    ins = (aot.spec((1, g + 1, v), jnp.float32), aot.spec((1, g, v), jnp.float32),
           aot.spec((1, g), jnp.int32), aot.spec((1, g), jnp.float32),
           aot.spec((1,), jnp.float32), aot.spec((1,), jnp.float32))
    b.lower("verify_test", fn, ins, dict(kind="verify", method="baseline",
                                         b=1, g=g, v=v))
    assert (tmp_path / "verify_test.hlo.txt").exists()
    e = b.entries[0]
    assert e["inputs"][0] == ["float32", [1, 2, 16]]
    assert e["outputs"][0] == ["int32", [1]]
    assert e["outputs"][1] == ["int32", [1, 2]]


def test_builder_cache_hit(tmp_path):
    b = aot.Builder(str(tmp_path))
    fn = make_verify_fn("baseline")
    v, g = 16, 1
    ins = (aot.spec((1, g + 1, v), jnp.float32), aot.spec((1, g, v), jnp.float32),
           aot.spec((1, g), jnp.int32), aot.spec((1, g), jnp.float32),
           aot.spec((1,), jnp.float32), aot.spec((1,), jnp.float32))
    b.lower("verify_test", fn, ins, dict(kind="verify"))
    mtime = os.path.getmtime(tmp_path / "verify_test.hlo.txt")
    b.lower("verify_test", fn, ins, dict(kind="verify"))  # cached: no rewrite
    assert os.path.getmtime(tmp_path / "verify_test.hlo.txt") == mtime


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="run `make artifacts` first")
def test_existing_manifest_schema():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    man = json.load(open(path))
    assert man["version"] == 1
    assert man["vocab_size"] >= 128
    kinds = {e["kind"] for e in man["artifacts"]}
    assert {"draft_step", "target_step", "target_score", "verify"} <= kinds
    for e in man["artifacts"]:
        f = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(f), e["name"]
        assert e["inputs"] and e["outputs"]
        if e["kind"] == "verify":
            assert e["method"] in ("baseline", "exact", "sigmoid", "sigmoid16")
            # sigmoid variants carry the runtime (alpha, beta) input
            n_in = len(e["inputs"])
            expect = 7 if e["method"].startswith("sigmoid") else 6
            assert n_in == expect, e["name"]
