"""L1 correctness: Pallas verification kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including vocab sizes that don't divide the tile,
single-tile and multi-tile grids) and logit scales; every kernel output is
compared against ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spec_verify import (
    verify_tiles_exact,
    verify_tiles_sigmoid,
    vmem_bytes,
)


def rand_probs(rng, b, g, v, scale=3.0):
    z = rng.randn(b, g, v).astype(np.float32) * scale
    return np.asarray(ref.softmax(jnp.asarray(z)))


shape_st = st.tuples(
    st.integers(1, 3),      # B
    st.integers(1, 6),      # G
    st.integers(2, 700),    # V
    st.sampled_from([8, 64, 128, 1024]),  # tile
    st.integers(0, 2**31 - 1),
)


@settings(max_examples=40, deadline=None)
@given(shape_st)
def test_exact_kernel_matches_ref(args):
    b, g, v, tile, seed = args
    rng = np.random.RandomState(seed)
    p = jnp.asarray(rand_probs(rng, b, g, v))
    q = jnp.asarray(rand_probs(rng, b, g, v))
    tau_k, a_k, b_k = verify_tiles_exact(p, q, tile=tile)
    tau_r, a_r, b_r = ref.ref_verify(p, q)
    np.testing.assert_allclose(tau_k, tau_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(a_k, a_r, rtol=1e-6, atol=1e-7)
    # b is a sum reduced in a different association order (per-tile partials
    # then cross-tile): allow f32 reassociation slack.
    np.testing.assert_allclose(b_k, b_r, rtol=1e-5, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    shape_st,
    st.sampled_from([(-10.0, 10.0), (-1e3, 1e3), (-1e4, 1e4), (-1e5, 1e5)]),
    st.floats(0.5, 30.0),
)
def test_sigmoid_kernel_matches_ref(args, alpha_beta, scale):
    b, g, v, tile, seed = args
    alpha, beta = alpha_beta
    rng = np.random.RandomState(seed)
    zp = jnp.asarray(rng.randn(b, g, v).astype(np.float32) * scale)
    zq = jnp.asarray(rng.randn(b, g, v).astype(np.float32) * scale)
    ab = jnp.asarray([alpha, beta], jnp.float32)
    tau_k, a_k, b_k = verify_tiles_sigmoid(zp, zq, ab, tile=tile)
    tau_r, a_r, b_r = ref.ref_verify_sigmoid(zp, zq, alpha, beta)
    np.testing.assert_allclose(tau_k, tau_r, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(a_k, a_r, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(b_k, b_r, rtol=1e-4, atol=1e-6)


def test_exact_identical_p_q_accepts_everything():
    rng = np.random.RandomState(0)
    p = jnp.asarray(rand_probs(rng, 2, 3, 97))
    tau, a, b = verify_tiles_exact(p, p, tile=32)
    assert np.all(np.asarray(tau) == 1.0)
    assert np.all(np.asarray(a) == 0.0)
    assert np.all(np.asarray(b) == 0.0)


def test_exact_zero_q_lanes_get_tau_one():
    # q = 0 on some lanes must not produce NaN/inf (guarded division).
    p = jnp.asarray([[[0.25, 0.25, 0.25, 0.25]]], jnp.float32)
    q = jnp.asarray([[[0.5, 0.5, 0.0, 0.0]]], jnp.float32)
    tau, a, b = verify_tiles_exact(p, q, tile=2)
    t = np.asarray(tau)[0, 0]
    assert np.all(np.isfinite(t))
    np.testing.assert_allclose(t, [0.5, 0.5, 1.0, 1.0])
    np.testing.assert_allclose(np.asarray(b)[0, 0], 0.5)


def test_tile_larger_than_vocab_is_clamped():
    rng = np.random.RandomState(1)
    p = jnp.asarray(rand_probs(rng, 1, 1, 5))
    q = jnp.asarray(rand_probs(rng, 1, 1, 5))
    tau, a, b = verify_tiles_exact(p, q, tile=1024)
    tau_r, a_r, b_r = ref.ref_verify(p, q)
    np.testing.assert_allclose(tau, tau_r, rtol=1e-6)
    np.testing.assert_allclose(b, b_r, rtol=1e-6)


def test_sigmoid_extreme_scale_saturates_tau_to_one():
    # The Table 2 +-1e5 failure mode: scaled logits collapse below f32
    # epsilon around sigma(0.5), every ratio becomes ~1, everything accepts.
    rng = np.random.RandomState(2)
    zp = jnp.asarray(rng.randn(1, 2, 64).astype(np.float32) * 5)
    zq = jnp.asarray(rng.randn(1, 2, 64).astype(np.float32) * 5)
    ab = jnp.asarray([-1e5, 1e5], jnp.float32)
    tau, a, b = verify_tiles_sigmoid(zp, zq, ab, tile=64)
    assert float(jnp.min(tau)) > 0.999
    # residual mass nearly vanishes (all sigmoids collapse toward sigma(0.5))
    assert float(jnp.max(b)) < 1e-2


def test_vmem_budget_within_sram():
    # Paper: n=1024 threads/block, A100 has 192KB SRAM/SM. After perf
    # iteration 1 a grid step holds (γ, n) tiles: fp16 fits at γ=20 with
    # the paper's n=1024; f32 needs n=512 at γ=20 (or γ≤10 at n=1024).
    assert vmem_bytes(20, dtype_bytes=2) <= 192 * 1024
    assert vmem_bytes(20, tile=512, dtype_bytes=4) <= 192 * 1024
    assert vmem_bytes(10, dtype_bytes=4) <= 192 * 1024
    # footprint grows linearly in gamma
    assert vmem_bytes(10) < vmem_bytes(20) <= 2 * vmem_bytes(10)


@pytest.mark.parametrize("v,tile,k", [(128, 128, 1), (129, 128, 2), (4096, 1024, 4)])
def test_partial_sum_tile_count(v, tile, k):
    rng = np.random.RandomState(3)
    p = jnp.asarray(rand_probs(rng, 1, 1, v))
    q = jnp.asarray(rand_probs(rng, 1, 1, v))
    # indirect check: outputs still match the oracle at these K values
    _, _, b_k = verify_tiles_exact(p, q, tile=tile)
    _, _, b_r = ref.ref_verify(p, q)
    np.testing.assert_allclose(b_k, b_r, rtol=1e-5)
