"""L2 model checks: shapes, causality, padding invariance, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

TINY = m.ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=2,
                     d_ff=64, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return m.init_params(TINY, seed=0)


def test_forward_shapes(params):
    toks = jnp.zeros((3, TINY.max_seq), jnp.int32)
    lens = jnp.asarray([5, 1, 32], jnp.int32)
    out = m.forward(params, TINY, toks, lens)
    assert out.shape == (3, TINY.max_seq, TINY.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_param_count_matches_pytree(params):
    leaves = jax.tree_util.tree_leaves(params)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    assert total == TINY.param_count()


def test_causality_future_tokens_do_not_affect_prefix(params):
    rng = np.random.RandomState(0)
    base = rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)).astype(np.int32)
    lens = jnp.asarray([10], jnp.int32)
    out1 = m.forward(params, TINY, jnp.asarray(base), lens)
    mutated = base.copy()
    mutated[0, 10:] = (mutated[0, 10:] + 7) % TINY.vocab_size  # beyond prefix
    out2 = m.forward(params, TINY, jnp.asarray(mutated), lens)
    # logits strictly inside the prefix are unchanged
    np.testing.assert_allclose(out1[0, :10], out2[0, :10], rtol=1e-6, atol=1e-6)


def test_next_logits_equals_forward_at_last_position(params):
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(3, TINY.vocab_size, (2, TINY.max_seq)), jnp.int32)
    lens = jnp.asarray([7, 13], jnp.int32)
    nl = m.next_logits(params, TINY, toks, lens)
    full = m.forward(params, TINY, toks, lens)
    np.testing.assert_allclose(nl[0], full[0, 6], rtol=1e-6)
    np.testing.assert_allclose(nl[1], full[1, 12], rtol=1e-6)


def test_logits_at_window_alignment(params):
    rng = np.random.RandomState(2)
    toks = jnp.asarray(rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)), jnp.int32)
    lens = jnp.asarray([20], jnp.int32)
    k = 4
    win = m.logits_at(params, TINY, toks, lens, k)
    full = m.forward(params, TINY, toks, lens)
    for j in range(k):
        np.testing.assert_allclose(win[0, j], full[0, 20 - k + j], rtol=1e-6)


def test_padding_rows_do_not_affect_each_other(params):
    """Batch invariance: row 0's logits identical whatever row 1 holds."""
    rng = np.random.RandomState(3)
    row = rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)).astype(np.int32)
    lens = jnp.asarray([9, 4], jnp.int32)
    other1 = rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)).astype(np.int32)
    other2 = rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)).astype(np.int32)
    o1 = m.next_logits(params, TINY, jnp.asarray(np.vstack([row, other1])), lens)
    o2 = m.next_logits(params, TINY, jnp.asarray(np.vstack([row, other2])), lens)
    np.testing.assert_allclose(o1[0], o2[0], rtol=1e-6)


def test_loss_decreases_with_training():
    from compile import train
    rng = np.random.RandomState(0)
    # a trivially learnable stream: repeating 16-token motif
    motif = rng.randint(3, TINY.vocab_size, 16)
    ids = np.tile(motif, 300).astype(np.int32)
    params, losses = train.train_model(
        "tiny", TINY, ids, steps=30, seed=0, batch=8, seq=24, lr=3e-3,
        log_every=29)
    assert losses[-1] < losses[0] * 0.8, losses


def test_loss_fn_ignores_padding():
    params = m.init_params(TINY, seed=0)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        3, TINY.vocab_size, (2, TINY.max_seq)), jnp.int32)
    lens = jnp.asarray([8, 8], jnp.int32)
    l1 = m.loss_fn(params, TINY, toks, lens)
    # garbage beyond the prefix must not change the loss
    toks2 = np.asarray(toks).copy()
    toks2[:, 8:] = 3
    l2 = m.loss_fn(params, TINY, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
