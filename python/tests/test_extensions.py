"""Extension features: fp16 sigmoid overflow repro + self-speculative
layer-skipping draft."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as m
from compile.verify_graph import make_verify_fn

TINY = m.ModelConfig(vocab_size=64, d_model=32, n_layers=4, n_heads=2,
                     d_ff=64, max_seq=32)


def inputs(seed, b, g, v, scale=5.0):
    rng = np.random.RandomState(seed)
    return (
        jnp.asarray(rng.randn(b, g + 1, v).astype(np.float32) * scale),
        jnp.asarray(rng.randn(b, g, v).astype(np.float32) * scale),
        jnp.asarray(rng.randint(0, v, (b, g)), jnp.int32),
        jnp.asarray(rng.rand(b, g).astype(np.float32)),
        jnp.asarray(rng.rand(b).astype(np.float32)),
        jnp.asarray(rng.rand(b).astype(np.float32)),
    )


class TestSigmoid16:
    def test_moderate_scale_matches_f32_sigmoid_decisions(self):
        # at ±1e3 fp16 arithmetic is safe: same accept/reject decisions
        ins = inputs(0, 2, 4, 96)
        ab = jnp.asarray([-1e3, 1e3], jnp.float32)
        a32 = make_verify_fn("sigmoid")(*ins, ab)
        a16 = make_verify_fn("sigmoid16")(*ins, ab)
        np.testing.assert_array_equal(np.asarray(a32[0]), np.asarray(a16[0]))

    def test_1e5_overflows_and_collapses(self):
        # (z - α) overflows fp16 -> inf/inf = NaN -> every test fails:
        # the Table 2 ±1e5 catastrophic row (WER 29.34, −10826% time)
        ins = inputs(1, 2, 4, 96)
        ab = jnp.asarray([-1e5, 1e5], jnp.float32)
        alen, out, tau = make_verify_fn("sigmoid16")(*ins, ab)
        assert np.all(np.asarray(alen) == 0), "NaN tau must reject everything"
        assert np.all(np.isnan(np.asarray(tau)))
        # while plain f32 sigmoid at the same scale accepts (nearly)
        # everything — tau collapses to ~1
        alen32, _, tau32 = make_verify_fn("sigmoid")(*ins, ab)
        assert np.asarray(alen32).sum() >= 6  # ≥ 6 of 8 drafts accepted
        assert np.all(np.asarray(tau32) > 0.99)

    def test_1e5_output_tokens_still_in_range(self):
        # the engine must not crash on the pathological regime
        ins = inputs(2, 1, 3, 64)
        ab = jnp.asarray([-1e5, 1e5], jnp.float32)
        _, out, _ = make_verify_fn("sigmoid16")(*ins, ab)
        emitted = np.asarray(out)[0, 0]
        assert 0 <= emitted < 64


class TestSelfSpeculative:
    def test_partial_forward_uses_prefix_of_layers(self):
        params = m.init_params(TINY, seed=0)
        toks = jnp.asarray(
            np.random.RandomState(0).randint(3, TINY.vocab_size, (1, TINY.max_seq)),
            jnp.int32)
        lens = jnp.asarray([10], jnp.int32)
        full = m.forward(params, TINY, toks, lens)
        half = m.forward(params, TINY, toks, lens, num_layers=2)
        # differs from the full model…
        assert not np.allclose(np.asarray(full[0, 9]), np.asarray(half[0, 9]))
        # …and equals a model whose later layers are deleted
        chopped = dict(params)
        chopped["layers"] = params["layers"][:2]
        chopped_out = m.forward(chopped, TINY, toks, lens)
        np.testing.assert_allclose(
            np.asarray(half), np.asarray(chopped_out), rtol=1e-6)

    def test_zero_extra_layers_clamped(self):
        params = m.init_params(TINY, seed=1)
        toks = jnp.zeros((1, TINY.max_seq), jnp.int32)
        lens = jnp.asarray([4], jnp.int32)
        out = m.forward(params, TINY, toks, lens, num_layers=0)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_half_depth_still_correlates_with_full(self):
        # layer-skipped logits should be a usable draft: top-1 agreement
        # well above chance on a *trained-ish* signal. Use random params —
        # correlation via the shared embedding/head is already nontrivial.
        params = m.init_params(TINY, seed=2)
        rng = np.random.RandomState(3)
        agree = 0
        total = 20
        for i in range(total):
            toks = jnp.asarray(rng.randint(3, TINY.vocab_size, (1, TINY.max_seq)),
                               jnp.int32)
            lens = jnp.asarray([8], jnp.int32)
            f = m.next_logits(params, TINY, toks, lens)
            h = jnp.take_along_axis(
                m.forward(params, TINY, toks, lens, num_layers=2),
                jnp.asarray([[[7]]]), axis=1)[:, 0, :]
            agree += int(jnp.argmax(f) == jnp.argmax(h))
        assert agree >= 2, f"only {agree}/{total} top-1 agreement"
